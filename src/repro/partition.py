"""Parameter partitioning: path-pattern -> PartitionSpec inference.

The spatial level of the paper's Algorithm 2 decides which GEMM dimension of
each weight is sharded (K -> reduction collectives, N -> free).  For the LM
substrate this materializes as the standard Megatron/FSDP layout:

* train regime: TP over ``model`` on the "wide" dim + FSDP over the DP axes
  on the opposite dim; optimizer states ZeRO-shard the same way.
* serve regime: TP only (weights replicated over DP so decode needs no
  weight gathers).

Patterns are matched against the ``/``-joined param path; the spec applies to
the LAST ndim dims named in the pattern (leading stack/scan dims get None).
Dims that don't divide the mapped axes silently fall back to None — one rule
table serves every arch x mesh cell.
"""

from __future__ import annotations

import re
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as shlib

# (path regex, spec for trailing dims).  "dp" is replaced by the DP axes,
# "tp" by the model axis.  First match wins.
_TRAIN_RULES: list[tuple[str, tuple]] = [
    # MoE expert banks (E, D, F) / (E, F, D): EP on E when divisible (the
    # fallback logic below drops non-dividing axes, which yields the "tp"
    # layout automatically for e.g. mixtral's 8 experts on a 16-way axis).
    (r"moe/w_(gate|up)$", ("ep", "dp", "tp_if_no_ep")),
    (r"moe/w_down$", ("ep", "tp_if_no_ep", "dp")),
    (r"moe/router(_bias)?$", (None, None)),
    (r"moe/shared/w_(gate|up)$", ("dp", "tp")),
    (r"moe/shared/w_down$", ("tp", "dp")),
    # MLA
    (r"attn/wdq$", ("dp", "tp")),
    (r"attn/wuq$", ("dp", "tp")),
    (r"attn/wdkv$", ("dp", None)),
    (r"attn/wukv$", ("dp", "tp")),
    # Attention projections
    (r"attn/w[qkv]$", ("dp", "tp")),
    (r"x?attn/w[qkv]$", ("dp", "tp")),
    (r"attn/wo$", ("tp", "dp")),
    (r"x?attn/wo$", ("tp", "dp")),
    (r"attn/b[qkv]$", (None,)),
    # MLP
    (r"mlp/w_(gate|up)$", ("dp", "tp")),
    (r"mlp/w_down$", ("tp", "dp")),
    # RWKV
    (r"tmix/w[rkvg]$", ("dp", "tp")),
    (r"tmix/wo$", ("tp", "dp")),
    (r"cmix/wk$", ("dp", "tp")),
    (r"cmix/wv$", ("tp", "dp")),
    (r"cmix/wr$", ("dp", "tp")),
    # Griffin
    (r"rec/w_[xy]$", ("dp", "tp")),
    (r"rec/w_[ai]$", ("dp", "tp")),
    (r"rec/w_out$", ("tp", "dp")),
    (r"rec/conv$", (None, "tp")),
    # Embeddings: vocab over model ONLY — FSDP-sharding d_model here forces
    # a full (V, D) gather + f32 grad inside the loss (measured +>10 GiB on
    # gemma-27b); vocab-sharded-at-rest is small enough (147 MB/dev @ 256k).
    (r"(^|/)emb$", ("tp", None)),
    (r"(^|/)unemb$", (None, "tp")),
    (r"(^|/)pos_emb$", (None, None)),
    (r"mtp/proj$", ("dp", "tp")),
]

_SERVE_OVERRIDES = {"dp": None}     # serve: TP only, replicate over DP


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _resolve(entry, mesh: Mesh, *, serve: bool, has_ep: bool):
    dp = shlib.dp_axes(mesh)
    if entry is None:
        return None
    if entry == "dp":
        return None if serve or not dp else dp
    if entry == "tp":
        return "model" if "model" in mesh.axis_names else None
    if entry == "ep":
        return "model" if has_ep and "model" in mesh.axis_names else None
    if entry == "tp_if_no_ep":
        return None if has_ep else (
            "model" if "model" in mesh.axis_names else None)
    return entry


def _fit_spec(shape: tuple, spec_entries: tuple, mesh: Mesh) -> P:
    """Prepend None for leading stack dims; drop non-dividing axes."""
    n_lead = len(shape) - len(spec_entries)
    entries = (None,) * max(n_lead, 0) + tuple(spec_entries)
    entries = entries[:len(shape)]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fixed = []
    for dim, e in zip(shape, entries):
        if e is None:
            fixed.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        kept, prod = [], 1
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        fixed.append(tuple(kept) if len(kept) > 1 else
                     (kept[0] if kept else None))
    return P(*fixed)


def param_specs(params, cfg, mesh: Mesh, *, regime: str = "train"):
    """Returns a pytree of PartitionSpec matching `params` (abstract ok)."""
    serve = regime == "serve"
    has_ep = (cfg is not None and getattr(cfg, "moe", None) is not None
              and "model" in mesh.axis_names
              and cfg.moe.num_experts % mesh.shape["model"] == 0)

    a2a = (cfg is not None and getattr(cfg, "moe", None) is not None
           and getattr(cfg.moe, "impl", "") == "a2a")
    world = 1
    for a in mesh.axis_names:
        world *= mesh.shape[a]
    ep2d = (a2a and cfg.moe.num_experts % world == 0)
    ep2d_axes = tuple(shlib.dp_axes(mesh)) + ("model",)

    def one(path, leaf):
        ps = _path_str(path)
        # Quantized marker leaves: q8 inherits the parent weight's spec
        # (same trailing shape); per-column scales shard like the parent
        # (their singleton dims drop automatically in _fit_spec).
        if ps.endswith("/q8") or (ps.endswith("/scale") and "ln" not in ps
                                  and "norm" not in ps and "/gn/" not in ps):
            ps = ps.rsplit("/", 1)[0]
        if ep2d and re.search(r"moe/w_(gate|up|down)$", ps):
            return _fit_spec(leaf.shape, (ep2d_axes, None, None), mesh)
        if a2a and "moe/shared" in ps:
            # a2a layout: shared expert FSDP-sharded at rest, gathered
            # per layer inside the shard_map (matches _moe_a2a's w_spec).
            dp = shlib.dp_axes(mesh) or None
            if re.search(r"w_(gate|up)$", ps):
                return _fit_spec(leaf.shape, (None, dp), mesh)
            if ps.endswith("w_down"):
                return _fit_spec(leaf.shape, (dp, None), mesh)
        # Quantized marker leaves ({"q8","scale"}) share the parent's spec on
        # q8 and replicate the scale.
        for pat, entries in _TRAIN_RULES:
            if re.search(pat, ps):
                resolved = tuple(
                    _resolve(e, mesh, serve=serve, has_ep=has_ep)
                    for e in entries)
                return _fit_spec(leaf.shape, resolved, mesh)
        return P()      # norms, biases, small vectors: replicated

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, cfg, mesh: Mesh, *, regime: str = "train"):
    specs = param_specs(params, cfg, mesh, regime=regime)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# Decode-state (KV cache / recurrent state) sharding rules: batch over DP,
# heads over model where divisible.
_CACHE_RULES: list[tuple[str, tuple]] = [
    (r"(^|/)(k|v)$", (None, "dp", "tp", None, None)),        # (L,B,H,S,dh)
    (r"(^|/)x[kv]$", (None, "dp", "tp", None, None)),        # whisper cross
    (r"c_kv$", (None, "dp", None, None)),                    # MLA latent
    (r"k_rope$", (None, "dp", None, None, None)),
    (r"tmix/s$", (None, "dp", "tp", None, None)),            # rwkv state
    (r"(tmix|cmix)/prev$", (None, "dp", None, None)),
    (r"(^|/)conv$", (None, "dp", None, "tp")),               # griffin conv
    (r"(^|/)h$", (None, "dp", "tp")),                        # griffin lru state
]


def cache_specs(state, mesh: Mesh):
    """PartitionSpecs for a decode-state pytree (ShapeDtypeStructs ok)."""

    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def one(path, leaf):
        ps = _path_str(path)
        for pat, entries in _CACHE_RULES:
            if re.search(pat, ps):
                # Right-align on the trailing dims like params do, but cache
                # rules are written for the full rank: trim from the left.
                trim = entries[max(0, len(entries) - len(leaf.shape)):]
                resolved = tuple(
                    _resolve(e, mesh, serve=False, has_ep=False)
                    for e in trim)
                spec = _fit_spec(leaf.shape, resolved, mesh)
                # KV fallback: when the head count doesn't divide the model
                # axis (qwen kv=2, mixtral kv=8, MQA kv=1), shard the cache
                # SEQUENCE over model instead — flash-decoding semantics via
                # GSPMD partial softmax; otherwise the cache replicates
                # model_n-fold (measured 60 GiB on mixtral decode_32k).
                if (re.search(r"(^|/)(k|v)$", ps) and len(leaf.shape) >= 4
                        and model_n > 1):
                    entries_ = list(spec) + [None] * (len(leaf.shape)
                                                      - len(spec))
                    h_dim, s_dim = len(leaf.shape) - 3, len(leaf.shape) - 2
                    if entries_[h_dim] is None and                             leaf.shape[s_dim] % model_n == 0:
                        entries_[s_dim] = "model"
                        spec = P(*entries_)
                return spec
        return P()

    return jax.tree_util.tree_map_with_path(one, state)


def cache_shardings(state, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(state, mesh),
                        is_leaf=lambda x: isinstance(x, P))
