"""Per-layer dataflow graphs — the planner's input representation.

The planner does not walk model code; it walks a :class:`DataflowGraph` of
:class:`LayerNode` s.  Two front-ends build graphs:

* :func:`edge_graph` — from an ``EdgeConfig`` (the paper's Table-I dense
  pipelines): one node per dense layer, batch-8, int8 deployment datatype.
* :func:`model_graph` — from a ``ModelConfig`` (the LM serving surface): one
  node per *distinct GEMM* of a decode step (wq/wk/wv/wo, the MLP matrices),
  annotated with the per-block repeat count so the planner prices a whole
  block and multiplies out.

Nodes carry everything the planner needs (operand extents, activation bytes,
weight bytes, MAC count) and nothing execution-specific; regimes and tile
shapes are the planner's output, not the graph's.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class LayerNode:
    """One GEMM-shaped stage of the pipeline."""
    index: int
    name: str
    n_in: int
    n_out: int
    act: str = "none"            # activation fused after the GEMM
    repeat: int = 1              # identical instances (LM: num_layers)
    itemsize: int = 1            # deployment datatype bytes (int8 default)

    @property
    def macs(self) -> int:
        return self.n_in * self.n_out

    def in_bytes(self, batch: int) -> int:
        return batch * self.n_in * self.itemsize

    def out_bytes(self, batch: int) -> int:
        # Activations hand off in f32 before requantization.
        return batch * self.n_out * 4

    def weight_bytes(self) -> int:
        return self.n_in * self.n_out * self.itemsize


@dataclasses.dataclass(frozen=True)
class DataflowGraph:
    name: str
    batch: int
    nodes: tuple[LayerNode, ...]
    kind: str = "edge"           # "edge" | "lm"

    @property
    def macs(self) -> int:
        return sum(n.macs * n.repeat for n in self.nodes)

    def __iter__(self) -> Iterable[LayerNode]:
        return iter(self.nodes)

    def __len__(self) -> int:
        return len(self.nodes)


def edge_graph(cfg) -> DataflowGraph:
    """Graph of an ``EdgeConfig`` dense pipeline (one node per layer)."""
    nodes = []
    last = len(cfg.layer_shapes) - 1
    for i, (n_in, n_out) in enumerate(cfg.layer_shapes):
        nodes.append(LayerNode(
            index=i, name=f"dense{i}", n_in=n_in, n_out=n_out,
            act=cfg.act if i != last else "none", itemsize=1))
    return DataflowGraph(name=cfg.name, batch=cfg.batch, nodes=tuple(nodes),
                         kind="edge")


def model_graph(cfg, *, batch: int = 1) -> DataflowGraph:
    """Graph of a ``ModelConfig`` decode step: the distinct per-block GEMMs.

    LM weights deploy in bf16 unless the planner's quantization rule flips a
    node to int8, so nodes carry ``itemsize=2`` here.
    """
    d, layers = cfg.d_model, cfg.num_layers
    nodes = [
        LayerNode(0, "attn.wq", d, cfg.q_dim, repeat=layers, itemsize=2),
        LayerNode(1, "attn.wk", d, cfg.kv_dim, repeat=layers, itemsize=2),
        LayerNode(2, "attn.wv", d, cfg.kv_dim, repeat=layers, itemsize=2),
        LayerNode(3, "attn.wo", cfg.q_dim, d, repeat=layers, itemsize=2),
    ]
    n_mlp_in = 2 if cfg.mlp_gated else 1
    d_ff = cfg.moe.d_ff_expert if cfg.moe is not None else cfg.d_ff
    nodes.append(LayerNode(4, "mlp.in", d, d_ff * n_mlp_in, repeat=layers,
                           itemsize=2))
    nodes.append(LayerNode(5, "mlp.out", d_ff, d, repeat=layers, itemsize=2))
    nodes.append(LayerNode(6, "unemb", d, cfg.padded_vocab, itemsize=2))
    return DataflowGraph(name=cfg.name, batch=batch, nodes=tuple(nodes),
                         kind="lm")
