"""DeploymentPlan — the serializable planner output + the keyed plan cache.

A plan is a pure-data record: the consumers (``models/edge.py``,
``serve/engine.py``, the benchmarks) execute it without re-running any
search.  The JSON schema (version ``PLAN_SCHEMA_VERSION``):

.. code-block:: json

    {
      "schema": 1, "network": "jet_tagger", "target": "tpu",
      "batch": 8, "key": "<sha256 over config+hardware+planner-version>",
      "layers": [
        {"index": 0, "name": "dense0", "n_in": 16, "n_out": 64,
         "regime": "tiled", "lare": 1.7,
         "spatial": {"p_k": 1, "p_n": 1, "band": 1},
         "api_tile": [32, 128, 128],
         "fuse_group": 0, "est_latency_s": 2.4e-06,
         "est_interval_s": 1.1e-06, "rules": ["DR1'(block=(32, 128, 128))"]},
        ...
      ],
      "boundaries": [{"after_layer": 2, "crossing_s": 3.1e-06,
                      "from_regime": "tiled", "to_regime": "pipeline"}],
      "totals": {"est_latency_s": ..., "est_interval_s": ...,
                 "inferences_per_s": ...},
      "serve": {"quantize_weights": true, "prefill_chunk": null}
    }

``plan_key`` hashes the *inputs* of planning (layer shapes, batch, target,
every hardware-model constant, planner version), so a cache hit is exactly
"same question asked again" — re-parameterizing ``hw.py`` or bumping the
planner invalidates stale artifacts automatically.  When planning runs under
a fitted :class:`repro.characterize.MachineModel`, its sha256 ``version``
rides in the key's ``extra`` payload (on top of the substituted constants
themselves), so plans made under a stale characterization self-invalidate
even if two models happen to collide on a fingerprinted subset.

Schema v2 (PR 2) additions — v1 artifacts still load unchanged:

* a top-level ``"kind"`` ("edge" | "lm") so consumers can pick an executor
  without re-deriving it from the config;
* the free-form ``serve`` section may carry the continuous-batching policy
  (``slots``, ``prefill_chunk``, ``admit_per_tick``, ``max_new_cap``) and a
  ``calibration`` record written back by ``plan.calibrate.feedback``;
* the multi-network ``FleetPlan`` artifact (``repro.plan.multinet``) embeds
  per-tenant ``DeploymentPlan`` dicts in this same schema.

Schema v3 (PR 4) — v1/v2 artifacts still load unchanged:

* a top-level ``"fusion_groups"`` section: the DR7' fusion DP's decision as
  an executable list of launch groups, each ``{"id", "layers",
  "est_latency_s", "vmem_bytes"}``.  ``models/edge.py`` executes one fused
  megakernel launch per multi-layer group (``kernels/fused_mlp``) instead of
  one launch per layer; whole-net groups appear when the boundary costs
  allow, per-layer groups are the fallback.  v1/v2 artifacts (which already
  carried per-layer ``fuse_group`` ids) derive the section on load, so old
  plans execute through the same group-driven path.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import warnings

PLAN_SCHEMA_VERSION = 3
PLANNER_VERSION = "plan-6"      # bump on any search/cost-model change

#: Top-level keys the current schema defines; loaders warn on (but keep
#: accepting) anything else, and ``repro check`` reports it as an info
#: finding (``plan.unknown-key``).
_KNOWN_PLAN_KEYS = frozenset({
    "schema", "kind", "network", "target", "batch", "key", "layers",
    "boundaries", "fusion_groups", "totals", "serve"})
# plan-6: serve sections gained the "resilience" knobs (breaker/retry/
# deadline — repro.faults.RESILIENCE_DEFAULTS); bumped so cached artifacts
# from earlier planners self-invalidate and pick the knobs up on re-plan.


def atomic_write_text(path: str | os.PathLike, text: str) -> pathlib.Path:
    """Crash-safe artifact write: tmp file in the same directory, then
    ``os.replace`` (atomic on POSIX and Windows).  A process killed
    mid-write leaves the OLD artifact intact instead of a truncated JSON
    that poisons every later cache read."""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_name(f"{p.name}.tmp.{os.getpid()}")
    try:
        tmp.write_text(text)
        os.replace(tmp, p)
    except BaseException:
        tmp.unlink(missing_ok=True)
        raise
    return p


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    index: int
    name: str
    n_in: int
    n_out: int
    regime: str                  # aie path: "pl"|"aie"; tpu: "pipeline"|"tiled"
    lare: float                  # the metric value that drove the decision
    p_k: int
    p_n: int
    band: int                    # 1-based band the layer's columns land in
    api_tile: tuple[int, int, int]   # AIE: mmul shape; TPU: Pallas blocks
    fuse_group: int              # launch-fusion group id (DR7')
    est_latency_s: float
    est_interval_s: float
    act: str = "none"
    repeat: int = 1
    rules: tuple[str, ...] = ()

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["api_tile"] = list(self.api_tile)
        d["rules"] = list(self.rules)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayerPlan":
        d = dict(d)
        d["api_tile"] = tuple(d["api_tile"])
        d["rules"] = tuple(d.get("rules", ()))
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """One DR7' launch group: the layers a single fused kernel executes."""
    id: int
    layers: tuple[int, ...]          # member layer indices, consecutive
    est_latency_s: float             # one dispatch + compute + fused epilogues
    vmem_bytes: int = 0              # union working set (0 = unknown/legacy)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["layers"] = list(self.layers)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FusionGroup":
        d = dict(d)
        d["layers"] = tuple(d["layers"])
        return cls(**d)


def _derive_fusion_groups(layers) -> tuple[FusionGroup, ...]:
    """Fusion groups from per-layer ``fuse_group`` ids (v1/v2 artifacts and
    planners that only annotate layers): consecutive layers sharing an id
    form one group; the group estimate is the members' summed estimate (the
    legacy per-launch accounting — no fused-epilogue discount is invented
    for plans whose planner never priced one)."""
    groups: list[FusionGroup] = []
    for l in layers:
        if groups and l.fuse_group == groups[-1].id:
            g = groups[-1]
            groups[-1] = FusionGroup(
                id=g.id, layers=g.layers + (l.index,),
                est_latency_s=g.est_latency_s + l.est_latency_s * l.repeat,
                vmem_bytes=g.vmem_bytes)
        else:
            groups.append(FusionGroup(
                id=l.fuse_group, layers=(l.index,),
                est_latency_s=l.est_latency_s * l.repeat))
    return tuple(groups)


@dataclasses.dataclass(frozen=True)
class BoundaryPlan:
    after_layer: int
    from_regime: str
    to_regime: str
    crossing_s: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "BoundaryPlan":
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class DeploymentPlan:
    network: str
    target: str                  # "aie" (paper-faithful) | "tpu" (executable)
    batch: int
    key: str
    layers: tuple[LayerPlan, ...]
    boundaries: tuple[BoundaryPlan, ...]
    est_latency_s: float
    est_interval_s: float
    serve: dict = dataclasses.field(default_factory=dict)
    kind: str = "edge"           # "edge" | "lm" (graph kind; v2 addition)
    fusion_groups: tuple[FusionGroup, ...] = ()    # v3 addition
    schema: int = PLAN_SCHEMA_VERSION

    @property
    def inferences_per_s(self) -> float:
        return self.batch / self.est_interval_s if self.est_interval_s else 0.0

    def layer(self, index: int) -> LayerPlan:
        return self.layers[index]

    def regimes(self) -> list[str]:
        return [l.regime for l in self.layers]

    def groups(self) -> list[list[int]]:
        """Executable launch groups as layer-index lists — the consumers'
        view of the DR7' decision.  Plans without an explicit section (the
        AIE target, hand-built plans) fall back to the per-layer
        ``fuse_group`` annotations."""
        gs = self.fusion_groups or _derive_fusion_groups(self.layers)
        return [list(g.layers) for g in gs]

    @property
    def itemsize(self) -> int:
        """Deployment weight-datatype bytes (mirrors the graph front-ends:
        edge nets deploy int8, LM weights land bf16 unless quantized)."""
        return 1 if self.kind == "edge" else 2

    def work(self) -> dict:
        """Plan-derived roofline work for ONE planned inference (edge: the
        whole pipeline; lm: one decode step — an LM plan's graph IS a
        decode step).

        Per-layer MACs and weight/activation bytes follow the same
        accounting as :mod:`repro.plan.graph` (activations hand off in f32
        before requantization), multiplied out by each layer's ``repeat``.
        ``launches`` counts dispatches: one per DR7' fusion group (times
        the group's repeat), which is exactly what the boundary cost model
        charges ``kernel_overhead_s`` for.  The profiler
        (:mod:`repro.obs.profile`) divides these by measured span time to
        get achieved FLOP/s and bytes/s."""
        its = self.itemsize
        by_index = {l.index: l for l in self.layers}

        def layer_work(l) -> dict:
            flops = 2.0 * self.batch * l.n_in * l.n_out * l.repeat
            weight_bytes = l.n_in * l.n_out * its * l.repeat
            act_bytes = (self.batch * l.n_in * its
                         + self.batch * l.n_out * 4) * l.repeat
            return {"flops": flops, "weight_bytes": weight_bytes,
                    "act_bytes": act_bytes}

        groups = self.fusion_groups or _derive_fusion_groups(self.layers)
        per_group = []
        totals = {"flops": 0.0, "weight_bytes": 0, "act_bytes": 0}
        launches = 0
        for g in groups:
            members = [by_index[i] for i in g.layers if i in by_index]
            gw = {"flops": 0.0, "weight_bytes": 0, "act_bytes": 0}
            for l in members:
                lw = layer_work(l)
                for k in gw:
                    gw[k] += lw[k]
            g_launches = max((l.repeat for l in members), default=1)
            launches += g_launches
            per_group.append({
                "id": g.id, "layers": list(g.layers),
                "est_latency_s": g.est_latency_s, "launches": g_launches,
                **gw,
            })
            for k in totals:
                totals[k] += gw[k]
        return {
            **totals,
            "bytes": totals["weight_bytes"] + totals["act_bytes"],
            "launches": launches,
            "itemsize": its,
            "per_group": per_group,
        }

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema": self.schema,
            "kind": self.kind,
            "network": self.network,
            "target": self.target,
            "batch": self.batch,
            "key": self.key,
            "layers": [l.to_dict() for l in self.layers],
            "boundaries": [b.to_dict() for b in self.boundaries],
            "fusion_groups": [g.to_dict() for g in self.fusion_groups],
            "totals": {
                "est_latency_s": self.est_latency_s,
                "est_interval_s": self.est_interval_s,
                "inferences_per_s": self.inferences_per_s,
            },
            "serve": dict(self.serve),
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "DeploymentPlan":
        # v1/v2 artifacts (PR 1/2) load unchanged; they are normalized to
        # the current schema on the way in ("kind" defaults to "edge",
        # "fusion_groups" is derived from the per-layer fuse_group ids those
        # schemas already carried).
        if d.get("schema") not in (1, 2, PLAN_SCHEMA_VERSION):
            raise ValueError(f"unsupported plan schema: {d.get('schema')!r}")
        unknown = sorted(set(d) - _KNOWN_PLAN_KEYS)
        if unknown:
            # Forward-compat: keep loading, but a typo'd section ("serv")
            # must not silently do nothing.  repro.check surfaces the same
            # condition as a plan.unknown-key info finding.
            warnings.warn(f"plan artifact for {d.get('network')!r} carries "
                          f"unknown top-level key(s) {unknown} (ignored)",
                          RuntimeWarning, stacklevel=2)
        layers = tuple(LayerPlan.from_dict(l) for l in d["layers"])
        if "fusion_groups" in d:
            fusion_groups = tuple(FusionGroup.from_dict(g)
                                  for g in d["fusion_groups"])
        else:
            fusion_groups = _derive_fusion_groups(layers)
        return cls(
            network=d["network"], target=d["target"], batch=d["batch"],
            key=d["key"],
            layers=layers,
            boundaries=tuple(BoundaryPlan.from_dict(b)
                             for b in d["boundaries"]),
            est_latency_s=d["totals"]["est_latency_s"],
            est_interval_s=d["totals"]["est_interval_s"],
            serve=dict(d.get("serve", {})),
            kind=d.get("kind", "edge"),
            fusion_groups=fusion_groups,
        )

    @classmethod
    def from_json(cls, s: str) -> "DeploymentPlan":
        return cls.from_dict(json.loads(s))

    def save(self, path: str | os.PathLike) -> pathlib.Path:
        return atomic_write_text(path, self.to_json() + "\n")

    @classmethod
    def load(cls, path: str | os.PathLike) -> "DeploymentPlan":
        return cls.from_json(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# Plan keying + cache
# ---------------------------------------------------------------------------

def _hw_fingerprint(hw_obj) -> dict:
    """Stable dict of a hardware dataclass's scalar constants."""
    out = {"class": type(hw_obj).__name__}
    for f in dataclasses.fields(hw_obj):
        v = getattr(hw_obj, f.name)
        out[f.name] = list(v) if isinstance(v, tuple) else v
    return out


def plan_key(graph, target: str, hw_objs: tuple, extra: dict | None = None) -> str:
    """sha256 over everything the planner's answer depends on."""
    payload = {
        "planner": PLANNER_VERSION,
        "network": graph.name,
        "kind": graph.kind,
        "batch": graph.batch,
        "target": target,
        "layers": [[n.name, n.n_in, n.n_out, n.act, n.repeat, n.itemsize]
                   for n in graph.nodes],
        "hw": [_hw_fingerprint(h) for h in hw_objs],
        "extra": extra or {},
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


class PlanCache:
    """In-memory + optional on-disk plan cache keyed on :func:`plan_key`.

    Disk layout: ``<dir>/<key>.json`` — one artifact per key, content equal
    to ``DeploymentPlan.to_json()``, so cached files double as the CLI's
    emitted artifacts.  Fleet artifacts (``repro.plan.multinet.FleetPlan``)
    live beside them as ``<dir>/<key>.fleet.json`` — same cache, second
    namespace, so ``plan_fleet`` answers repeat questions from cache exactly
    like ``get_or_plan`` does for single nets.
    """

    def __init__(self, directory: str | os.PathLike | None = None):
        self._mem: dict[str, DeploymentPlan] = {}
        self._fleets: dict[str, object] = {}
        self.directory = pathlib.Path(directory) if directory else None
        # Chaos hook (repro.faults): when a FaultInjector is armed here,
        # "cache.read" faults make a cached artifact read corrupt — the
        # same path a real truncated file takes.
        self.injector = None
        self.corrupt_reads = 0

    def _read_artifact(self, path: pathlib.Path, loader, what: str):
        """Load one cached artifact; corrupt/truncated JSON is a cache MISS
        (warn + re-plan), never an exception — a half-written file from a
        crashed process must not wedge every later deployment."""
        if self.injector is not None:
            spec = self.injector.fire("cache.read", tenant=what)
            if spec is not None and spec.kind == "cache_corruption":
                self.corrupt_reads += 1
                warnings.warn(
                    f"injected corrupt {what} artifact {path.name}; "
                    f"treating as cache miss", RuntimeWarning,
                    stacklevel=3)
                return None
        try:
            return loader(path)
        except (json.JSONDecodeError, KeyError, ValueError, TypeError,
                OSError) as exc:
            self.corrupt_reads += 1
            warnings.warn(
                f"corrupt {what} artifact {path} ({exc.__class__.__name__}: "
                f"{exc}); treating as cache miss", RuntimeWarning,
                stacklevel=3)
            return None

    def get(self, key: str) -> DeploymentPlan | None:
        if key in self._mem:
            return self._mem[key]
        if self.directory is not None:
            p = self.directory / f"{key}.json"
            if p.exists():
                plan = self._read_artifact(p, DeploymentPlan.load, "plan")
                if plan is None:
                    return None
                self._mem[key] = plan
                return plan
        return None

    def put(self, plan: DeploymentPlan) -> DeploymentPlan:
        self._mem[plan.key] = plan
        if self.directory is not None:
            plan.save(self.directory / f"{plan.key}.json")
        return plan

    def get_fleet(self, key: str):
        """Cached ``FleetPlan`` under its serve-scoped store key, or None."""
        if key in self._fleets:
            return self._fleets[key]
        if self.directory is not None:
            p = self.directory / f"{key}.fleet.json"
            if p.exists():
                from repro.plan.multinet import FleetPlan
                fleet = self._read_artifact(p, FleetPlan.load, "fleet")
                if fleet is None:
                    return None
                self._fleets[key] = fleet
                return fleet
        return None

    def put_fleet(self, fleet, *, key: str | None = None):
        """Store a fleet under ``key`` (the serve-scoped store key; the
        fleet's own planner key when omitted)."""
        key = key if key is not None else fleet.key
        self._fleets[key] = fleet
        if self.directory is not None:
            fleet.save(self.directory / f"{key}.fleet.json")
        return fleet

    def clear(self):
        self._mem.clear()
        self._fleets.clear()

    def __len__(self) -> int:
        return len(self._mem) + len(self._fleets)


_DEFAULT_CACHE: PlanCache | None = None


def default_cache() -> PlanCache:
    """Process-wide cache; set ``REPRO_PLAN_CACHE_DIR`` to persist to disk."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = PlanCache(os.environ.get("REPRO_PLAN_CACHE_DIR"))
    return _DEFAULT_CACHE
