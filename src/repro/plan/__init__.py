"""Deployment planner: LARE-driven placement + plan compilation.

The subsystem that composes the paper's decision procedure end-to-end:
``graph`` builds per-layer dataflow graphs from configs, ``planner`` runs
LARE + two-level tiling + column/band + boundary-cost search over them, and
``artifact`` serializes the result as a cache-keyed ``DeploymentPlan`` JSON
that ``models/edge.py``, ``serve/engine.py`` and the benchmarks execute.
``multinet`` extends the allocator to N co-resident networks sharing one
array (``plan_fleet`` -> ``FleetPlan``, consumed by ``repro.serve.Router``),
``calibrate.feedback`` writes measured latencies back into the cache, and
``calibrate.recalibrate_fleet`` replans a whole fleet from router
measurements (the drift-triggered autotune loop).  Every entry point accepts
``machine_model=`` — a fitted :class:`repro.characterize.MachineModel`
replacing the hand-tuned ``hw.py`` constants.

CLI: ``PYTHONPATH=src python -m repro.plan jet_tagger`` (see ``__main__``;
naming several nets plans them as a fleet; ``--machine-model model.json``
plans under a fitted characterization artifact).
"""

from repro.plan.artifact import (BoundaryPlan, DeploymentPlan, FusionGroup,
                                 LayerPlan, PlanCache, default_cache,
                                 plan_key)
from repro.plan.calibrate import (calibrated_cpu_model, feedback,
                                  measurements_from_engines,
                                  recalibrate_fleet)
from repro.plan.graph import DataflowGraph, LayerNode, edge_graph, model_graph
from repro.plan.multinet import FleetPlan, TenantPlan, plan_fleet
from repro.plan.planner import as_graph, get_or_plan, plan_deployment

__all__ = [
    "BoundaryPlan", "DataflowGraph", "DeploymentPlan", "FleetPlan",
    "FusionGroup", "LayerNode", "LayerPlan", "PlanCache", "TenantPlan",
    "as_graph",
    "calibrated_cpu_model", "default_cache", "edge_graph", "feedback",
    "get_or_plan", "measurements_from_engines", "model_graph",
    "plan_deployment", "plan_fleet", "plan_key", "recalibrate_fleet",
]
