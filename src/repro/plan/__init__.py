"""Deployment planner: LARE-driven placement + plan compilation.

The subsystem that composes the paper's decision procedure end-to-end:
``graph`` builds per-layer dataflow graphs from configs, ``planner`` runs
LARE + two-level tiling + column/band + boundary-cost search over them, and
``artifact`` serializes the result as a cache-keyed ``DeploymentPlan`` JSON
that ``models/edge.py``, ``serve/engine.py`` and the benchmarks execute.

CLI: ``PYTHONPATH=src python -m repro.plan jet_tagger`` (see ``__main__``).
"""

from repro.plan.artifact import (BoundaryPlan, DeploymentPlan, LayerPlan,
                                 PlanCache, default_cache, plan_key)
from repro.plan.calibrate import calibrated_cpu_model
from repro.plan.graph import DataflowGraph, LayerNode, edge_graph, model_graph
from repro.plan.planner import as_graph, get_or_plan, plan_deployment

__all__ = [
    "BoundaryPlan", "DataflowGraph", "DeploymentPlan", "LayerNode",
    "LayerPlan", "PlanCache", "as_graph", "calibrated_cpu_model",
    "default_cache", "edge_graph", "get_or_plan", "model_graph", "plan_key",
    "plan_deployment",
]
