"""Calibrate a TPU machine model against THIS host's measured kernel path.

The planner's latency estimates come from a :class:`~repro.hw.TpuV5e`
instance.  On a real v5e the stock constants apply; on the CPU smoke path
(Pallas ``interpret=True``) every launch is dominated by the interpreter, so
planned-vs-measured comparisons need a machine model whose *throughput* and
*per-launch overhead* describe the interpreter, not the MXU.

:func:`calibrated_cpu_model` times jitted multi-launch int8 pipelines — the
same shape of computation the plan executor runs — at several (depth, width)
points, least-squares fits ``t = launches * overhead + padded_ops / peak``,
and returns a ``TpuV5e`` with those constants substituted.  ``padded_ops``
(not logical FLOPs) is the regressor because ``plan_api``'s efficiency term
is exactly the padding-waste product: fitting logical ops would double-count
the waste.  Everything else (the planner search, the plan schema, the
executors) is unchanged — which is the point: one decision procedure,
re-parameterized per substrate.
"""

from __future__ import annotations

import dataclasses
import time

from repro import hw as hwlib

_BM, _BK, _BN = 32, 128, 128


def feedback(plan, measured_latency_s: float, *, cache=None):
    """Write a measured end-to-end latency back into the plan cache.

    The plan's per-layer/boundary estimates are rescaled by
    ``measured / planned`` and a ``calibration`` record lands in the plan's
    ``serve`` section; the updated plan is re-``put`` under its ORIGINAL key,
    so the next ``get_or_plan`` with the same question returns calibrated
    costs instead of the cold model (the small autotuning loop: plans improve
    across runs).  Tile/regime decisions are untouched — only the cost
    annotations move."""
    from repro.plan.artifact import default_cache
    if measured_latency_s <= 0:
        raise ValueError(f"measured latency must be > 0, "
                         f"got {measured_latency_s}")
    if plan.est_latency_s <= 0:
        raise ValueError("plan has no positive latency estimate to calibrate")
    # The TPU path's total carries a fixed entry-dispatch overhead on top of
    # the per-layer/boundary parts; scale only the parts so the invariant
    # est_latency == sum(parts) + overhead survives calibration (a naive
    # proportional rescale would double-count the overhead into the layers).
    parts = sum(l.est_latency_s * l.repeat for l in plan.layers) \
        + sum(b.crossing_s for b in plan.boundaries)
    overhead = max(plan.est_latency_s - parts, 0.0)
    if parts > 0 and measured_latency_s > overhead:
        scale = (measured_latency_s - overhead) / parts
    else:                           # degenerate: fall back to proportional
        scale = measured_latency_s / plan.est_latency_s
    layers = tuple(dataclasses.replace(
        l, est_latency_s=l.est_latency_s * scale,
        est_interval_s=l.est_interval_s * scale) for l in plan.layers)
    bounds = tuple(dataclasses.replace(b, crossing_s=b.crossing_s * scale)
                   for b in plan.boundaries)
    calibrated = dataclasses.replace(
        plan, layers=layers, boundaries=bounds,
        est_latency_s=measured_latency_s,
        est_interval_s=plan.est_interval_s
        * (measured_latency_s / plan.est_latency_s),
        serve={**plan.serve,
               "calibration": {"measured_latency_s": measured_latency_s,
                               "scale": scale}})
    cache = cache if cache is not None else default_cache()
    cache.put(calibrated)
    return calibrated


def _time_call(fn, *args, iters: int = 5) -> float:
    import jax
    jax.block_until_ready(fn(*args))      # warmup / compile
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def calibrated_cpu_model(*, batch: int = 8,
                         base: hwlib.TpuV5e = hwlib.TPU_V5E) -> hwlib.TpuV5e:
    """Fit (kernel_overhead_s, effective peak) to measured interpret-mode
    int8 GEMM pipelines and return the re-parameterized machine model."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops as kops

    def pipeline(width: int, depth: int):
        ws = jnp.ones((depth, width, width), jnp.int8)
        sc = jnp.ones((width,), jnp.float32)
        bk = bn = min(_ceil_to(width, 128), 512)

        @jax.jit
        def f(x):
            h = x
            for i in range(depth):
                y = kops.gemm_int8(h, ws[i], sc, 1.0, block_m=_BM,
                                   block_k=bk, block_n=bn,
                                   out_dtype=jnp.float32)
                h = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
            return h

        x = jnp.ones((batch, width), jnp.int8)
        ops = depth * 2.0 * _ceil_to(batch, _BM) \
            * _ceil_to(width, bk) * _ceil_to(width, bn)
        return _time_call(f, x), depth, ops

    points = [pipeline(128, 2), pipeline(128, 6), pipeline(512, 2)]
    a = np.array([[float(d), ops] for _, d, ops in points])
    t = np.array([ti for ti, _, _ in points])
    (overhead, inv_peak), *_ = np.linalg.lstsq(a, t, rcond=None)
    peak = 1.0 / inv_peak if inv_peak > 1e-15 else 1e12
    overhead = max(float(overhead), 1e-6)
    return dataclasses.replace(
        base,
        peak_int8_ops=max(peak, 1e6),
        peak_bf16_flops=max(peak / 2, 5e5),
        hbm_bw=1e15,                      # interpreter is compute/overhead-bound
        kernel_overhead_s=overhead,
    )
