"""Calibrate a TPU machine model against THIS host's measured kernel path.

The planner's latency estimates come from a :class:`~repro.hw.TpuV5e`
instance.  On a real v5e the stock constants apply; on the CPU smoke path
(Pallas ``interpret=True``) every launch is dominated by the interpreter, so
planned-vs-measured comparisons need a machine model whose *throughput* and
*per-launch overhead* describe the interpreter, not the MXU.

:func:`calibrated_cpu_model` times jitted multi-launch int8 pipelines — the
same shape of computation the plan executor runs — at several (depth, width)
points, least-squares fits ``t = launches * overhead + padded_ops / peak``,
and returns a ``TpuV5e`` with those constants substituted.  ``padded_ops``
(not logical FLOPs) is the regressor because ``plan_api``'s efficiency term
is exactly the padding-waste product: fitting logical ops would double-count
the waste.  Everything else (the planner search, the plan schema, the
executors) is unchanged — which is the point: one decision procedure,
re-parameterized per substrate.

The sweep/fit machinery itself lives in :mod:`repro.characterize`, which
generalizes this 2-constant fit to the planner's full cost-term set (GEMM
throughput per dtype, dispatch overhead, DR7 boundary bytes, band-2
contention) and packages the result as a versioned ``MachineModel``
artifact; this module keeps the calibration-feedback half of the loop:
:func:`feedback` writes one plan's measured latency back into the cache, and
:func:`recalibrate_fleet` replans a whole ``FleetPlan`` in place from router
measurements (the drift-triggered fleet autotune).
"""

from __future__ import annotations

import dataclasses

from repro import hw as hwlib


def feedback(plan, measured_latency_s: float, *, cache=None):
    """Write a measured end-to-end latency back into the plan cache.

    The plan's per-layer/boundary estimates are rescaled by
    ``measured / planned`` and a ``calibration`` record lands in the plan's
    ``serve`` section; the updated plan is re-``put`` under its ORIGINAL key,
    so the next ``get_or_plan`` with the same question returns calibrated
    costs instead of the cold model (the small autotuning loop: plans improve
    across runs).  Tile/regime decisions are untouched — only the cost
    annotations move."""
    from repro.plan.artifact import default_cache
    if measured_latency_s <= 0:
        raise ValueError(f"measured latency must be > 0, "
                         f"got {measured_latency_s}")
    if plan.est_latency_s <= 0:
        raise ValueError("plan has no positive latency estimate to calibrate")
    # The TPU path's total carries a fixed entry-dispatch overhead on top of
    # the per-layer/boundary parts; scale only the parts so the invariant
    # est_latency == sum(parts) + overhead survives calibration (a naive
    # proportional rescale would double-count the overhead into the layers).
    parts = sum(l.est_latency_s * l.repeat for l in plan.layers) \
        + sum(b.crossing_s for b in plan.boundaries)
    overhead = max(plan.est_latency_s - parts, 0.0)
    if parts > 0 and measured_latency_s > overhead:
        scale = (measured_latency_s - overhead) / parts
    else:                           # degenerate: fall back to proportional
        scale = measured_latency_s / plan.est_latency_s
    layers = tuple(dataclasses.replace(
        l, est_latency_s=l.est_latency_s * scale,
        est_interval_s=l.est_interval_s * scale) for l in plan.layers)
    bounds = tuple(dataclasses.replace(b, crossing_s=b.crossing_s * scale)
                   for b in plan.boundaries)
    calibrated = dataclasses.replace(
        plan, layers=layers, boundaries=bounds,
        est_latency_s=measured_latency_s,
        est_interval_s=plan.est_interval_s
        * (measured_latency_s / plan.est_latency_s),
        serve={**plan.serve,
               "calibration": {"measured_latency_s": measured_latency_s,
                               "scale": scale}})
    cache = cache if cache is not None else default_cache()
    cache.put(calibrated)
    return calibrated


def recalibrate_fleet(fleet, measurements: dict, *, cache=None,
                      budget_factor: float | None = None):
    """Recalibrate a whole :class:`~repro.plan.multinet.FleetPlan` from
    measured per-tenant latencies and replan it IN PLACE.

    ``measurements`` maps ``net_id -> measured seconds`` (a robust statistic
    such as the router's per-tenant p50).  Each measured tenant's plan goes
    through :func:`feedback` (cost rescale under the parts+overhead
    invariant, written back to the cache under its original key), its latency
    budget is re-derived from the calibrated latency using the SAME headroom
    factor the original fleet was planned with (unless ``budget_factor``
    overrides it), and the fleet totals are recomputed.  Tiles, regimes and
    column assignments are untouched — only costs and budgets move, which is
    what lets the serving router swap the replanned fleet in without
    rebuilding engines.  This closes fleet-wide the autotune loop
    :func:`feedback` closes for single plans.
    """
    tenants = []
    for tp in fleet.tenants:
        m = measurements.get(tp.net_id)
        if m is not None and m > 0 and tp.plan.est_latency_s > 0:
            plan = feedback(tp.plan, m, cache=cache)
        else:
            plan = tp.plan
        planned = tp.plan.est_latency_s + tp.crossing_s
        factor = budget_factor if budget_factor is not None else (
            tp.latency_budget_s / planned if planned > 0 else 2.0)
        tenants.append(dataclasses.replace(
            tp, plan=plan,
            latency_budget_s=factor * (plan.est_latency_s + tp.crossing_s)))
    return dataclasses.replace(
        fleet, tenants=tuple(tenants),
        est_latency_s=max(t.total_latency_s for t in tenants))


def measurements_from_engines(engines: dict) -> dict:
    """``net_id -> measured seconds`` from a dict of live engines — the
    robust per-engine statistic (windowed p50 when the engine tracks one,
    mean otherwise), skipping engines with nothing recorded yet.  This is
    the glue :func:`recalibrate_fleet` needs when driven from a
    :class:`repro.deploy.Deployment` instead of the router's metrics."""
    out = {}
    for net_id, eng in engines.items():
        m = getattr(eng, "measured_p50_s", 0.0) \
            or getattr(eng, "measured_mean_s", 0.0)
        if m > 0:
            out[net_id] = m
    return out


_CPU_MODEL_MEMO: dict = {}


def cpu_model_memoized(*, batch: int = 8,
                       base: hwlib.TpuV5e = hwlib.TPU_V5E) -> bool:
    """Whether :func:`calibrated_cpu_model` would answer from its memo (no
    re-timing) for these arguments — consumers report cache provenance with
    this instead of reaching into the private memo."""
    return (batch, base) in _CPU_MODEL_MEMO


def calibrated_cpu_model(*, batch: int = 8,
                         base: hwlib.TpuV5e = hwlib.TPU_V5E,
                         fresh: bool = False) -> hwlib.TpuV5e:
    """Fit (kernel_overhead_s, effective peak) to measured interpret-mode
    int8 GEMM pipelines and return the re-parameterized machine model.

    A thin wrapper over the characterization harness: the legacy 3-point
    ``calibrate`` grid of the ``gemm_int8`` term, fitted by
    :func:`repro.characterize.fit_term`.  ``hbm_bw`` stays effectively
    infinite because the interpreter is compute/overhead-bound; run the full
    ``python -m repro.characterize`` sweep for a model that also fits the
    boundary and contention terms.

    The fit is memoized per (batch, base) for the process — every consumer
    (facade, benchmarks, examples) shares one calibration instead of
    re-timing the sweep; ``fresh=True`` forces a re-fit under current load.
    """
    memo_key = (batch, base)
    if not fresh and memo_key in _CPU_MODEL_MEMO:
        return _CPU_MODEL_MEMO[memo_key]
    from repro.characterize import fit_term, run_term
    samples = run_term("gemm_int8", sweep="calibrate", batch=batch)
    tf = fit_term("gemm_int8", samples)
    model = dataclasses.replace(
        base,
        peak_int8_ops=tf.constants["peak_int8_ops"],
        peak_bf16_flops=max(tf.constants["peak_int8_ops"] / 2, 5e5),
        hbm_bw=1e15,                      # interpreter is compute/overhead-bound
        kernel_overhead_s=tf.constants["kernel_overhead_s"],
    )
    _CPU_MODEL_MEMO[memo_key] = model
    return model
