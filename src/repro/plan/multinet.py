"""Multi-network co-residency planner (paper Section V-C).

Section V-C's deployments place *multiple* networks on the AI Engine array at
once: each net keeps its own spatial pipeline, but all of them draw columns
from the same ``usable_cols`` budget and every spilled layer raises the
shared band-2 contention penalty.  This module extends the single-net
allocator (:mod:`repro.plan.planner`) to N :class:`DataflowGraph` s:

* ``target="aie"`` — joint column packing.  Every net runs its own LARE pass
  (per-net ``pl_budget``), then ALL nets' AIE layers enter one
  :func:`planner._resolve_columns` call keyed by ``(tenant, layer)``: the
  shrink-vs-spill rule now trades one net's split width against another net's
  spill penalty, exactly the Fig.-6 economics applied fleet-wide.  Tenants
  receive contiguous, non-overlapping band-1 column ranges
  (``col_offset``/``cols``), and each net's off-array hand-off is charged a
  DR7 crossing (:func:`repro.core.boundary.crossing_cost_aie`) — co-resident
  nets stream results out through the same PLIO boundary.

* ``target="tpu"`` — the executable path: nets time-share one chip, so each
  is planned by the single-net TPU search, the hand-off between co-scheduled
  launch chains is charged :func:`crossing_cost_tpu`, and the plan's
  ``serve`` section gains the continuous-batching policy the runtime reads
  (``slots`` split across LM tenants, ``prefill_chunk``).

The output is a :class:`FleetPlan` (schema v2): per-tenant
:class:`DeploymentPlan` s plus column assignments and the latency budgets the
serving router (:mod:`repro.serve.router`) enforces.  ``FleetPlan.load`` also
accepts a PR-1 v1 ``DeploymentPlan`` artifact and wraps it as a
single-tenant fleet, so existing plan files keep working.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import warnings

from repro.core import boundary
from repro.faults import RESILIENCE_DEFAULTS
from repro.plan import planner
from repro.plan.artifact import (PLAN_SCHEMA_VERSION, PLANNER_VERSION,
                                 DeploymentPlan, atomic_write_text,
                                 default_cache)

# Default headroom between planned and enforced latency: the router flags a
# tenant when measured latency exceeds budget_factor x planned (matching the
# repo-wide planned-vs-measured 2x acceptance band).
DEFAULT_BUDGET_FACTOR = 2.0

#: Top-level keys the current fleet schema defines (see artifact.py's
#: _KNOWN_PLAN_KEYS for the per-plan equivalent).
_KNOWN_FLEET_KEYS = frozenset({
    "schema", "kind", "name", "target", "key", "tenants", "totals"})

# The serve-policy knobs and their defaults, in one place: plan_fleet's
# signature AND the serve-scoped fleet-cache key derive from this dict, so
# they cannot drift apart (repro.deploy computes store keys from it too).
SERVE_DEFAULTS = {
    "budget_factor": DEFAULT_BUDGET_FACTOR,
    "serve_slots_total": 8,
    "prefill_chunk": 8,
    "queue_depth_factor": 4,
}


def _band1_cols(plan: DeploymentPlan) -> int:
    """Band-1 array columns a plan occupies (0 off the AIE target)."""
    if plan.target != "aie":
        return 0
    return sum(l.p_k for l in plan.layers
               if l.regime == "aie" and l.band == 1)


@dataclasses.dataclass(frozen=True)
class TenantPlan:
    """One network's slice of the fleet: its plan, its columns, its budget."""
    net_id: str                  # unique within the fleet (router dispatch key)
    plan: DeploymentPlan
    col_offset: int              # first band-1 column on the array (aie; 0 tpu)
    cols: int                    # band-1 columns occupied (0 on tpu)
    crossing_s: float            # DR7 off-array/inter-chain hand-off charge
    latency_budget_s: float      # enforced by the serving router

    @property
    def total_latency_s(self) -> float:
        """Planned per-inference latency including the net-boundary charge."""
        return self.plan.est_latency_s + self.crossing_s

    def to_dict(self) -> dict:
        return {
            "net_id": self.net_id,
            "col_offset": self.col_offset,
            "cols": self.cols,
            "crossing_s": self.crossing_s,
            "latency_budget_s": self.latency_budget_s,
            "plan": self.plan.to_dict(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TenantPlan":
        return cls(net_id=d["net_id"], plan=DeploymentPlan.from_dict(d["plan"]),
                   col_offset=d["col_offset"], cols=d["cols"],
                   crossing_s=d["crossing_s"],
                   latency_budget_s=d["latency_budget_s"])


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """N co-resident deployments on one array, with per-tenant budgets."""
    name: str
    target: str
    key: str
    tenants: tuple[TenantPlan, ...]
    est_latency_s: float         # worst tenant (spatially concurrent nets)
    schema: int = PLAN_SCHEMA_VERSION

    def tenant(self, net_id: str) -> TenantPlan:
        for t in self.tenants:
            if t.net_id == net_id:
                return t
        raise KeyError(f"no tenant {net_id!r} in fleet {self.name!r}")

    @property
    def net_ids(self) -> list[str]:
        return [t.net_id for t in self.tenants]

    @property
    def band1_cols_used(self) -> int:
        return sum(t.cols for t in self.tenants)

    # -- serialization ----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema": self.schema,
            "kind": "fleet",
            "name": self.name,
            "target": self.target,
            "key": self.key,
            "tenants": [t.to_dict() for t in self.tenants],
            "totals": {
                "est_latency_s": self.est_latency_s,
                "band1_cols_used": self.band1_cols_used,
            },
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "FleetPlan":
        if "tenants" not in d:
            # A bare DeploymentPlan artifact (schema v1 or v2): wrap it as a
            # single-tenant fleet so PR-1 plan files keep loading.
            return cls.from_plan(DeploymentPlan.from_dict(d))
        if d.get("schema") not in (1, PLAN_SCHEMA_VERSION):
            raise ValueError(f"unsupported fleet schema: {d.get('schema')!r}")
        unknown = sorted(set(d) - _KNOWN_FLEET_KEYS)
        if unknown:
            # Forward-compat preserved; repro.check reports the same
            # condition as a plan.unknown-key info finding.
            warnings.warn(f"fleet artifact {d.get('name')!r} carries "
                          f"unknown top-level key(s) {unknown} (ignored)",
                          RuntimeWarning, stacklevel=2)
        tenants = tuple(TenantPlan.from_dict(t) for t in d["tenants"])
        return cls(name=d["name"], target=d["target"], key=d["key"],
                   tenants=tenants,
                   est_latency_s=d["totals"]["est_latency_s"])

    @classmethod
    def from_json(cls, s: str) -> "FleetPlan":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_plan(cls, plan: DeploymentPlan, *,
                  budget_factor: float = DEFAULT_BUDGET_FACTOR) -> "FleetPlan":
        """Wrap a single-net :class:`DeploymentPlan` as a one-tenant fleet."""
        cols = _band1_cols(plan)
        tenant = TenantPlan(
            net_id=plan.network, plan=plan, col_offset=0, cols=cols,
            crossing_s=0.0,
            latency_budget_s=budget_factor * plan.est_latency_s)
        return cls(name=plan.network, target=plan.target,
                   key=f"fleet:{plan.key}", tenants=(tenant,),
                   est_latency_s=plan.est_latency_s)

    def save(self, path: str | os.PathLike) -> pathlib.Path:
        return atomic_write_text(path, self.to_json() + "\n")

    @classmethod
    def load(cls, path: str | os.PathLike) -> "FleetPlan":
        return cls.from_json(pathlib.Path(path).read_text())


# ---------------------------------------------------------------------------
# Fleet planning
# ---------------------------------------------------------------------------

def _fleet_key(graphs, target: str, opts: dict) -> str:
    """sha256 over the ordered per-net plan keys — same nets, same order,
    same hardware and knobs => same fleet answer.  Deliberately EXCLUDES the
    serve-policy knobs: per-tenant plan keys derive from this key, and the
    calibration feedback parked under them must survive a serve-policy
    change (only the planner's question is the cache's question)."""
    payload = {
        "planner": PLANNER_VERSION,
        "fleet": [planner._key_for(g, target, opts) for g in graphs],
        "target": target,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def _serve_scoped_key(key: str, serve_kw: dict) -> str:
    """The FLEET-cache store key: the planner key plus the serve knobs, so a
    cached fleet can never override the slots/chunking/budgets a later call
    asked for (they are not part of the planner key, by design)."""
    blob = json.dumps({"key": key, "serve": serve_kw}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()


def fleet_store_key(cfgs, *, target: str = "tpu", batch: int | None = None,
                    **kw) -> str:
    """The store key :func:`plan_fleet` will use for these cfgs + knobs —
    THE way to predict a fleet-cache hit (``repro.deploy``'s plan stage
    reports its ``cached`` flag with it).  Serve knobs default exactly as in
    ``plan_fleet`` (both read ``SERVE_DEFAULTS``); remaining ``kw`` are
    planner knobs."""
    graphs = [planner.as_graph(c, batch=batch) for c in cfgs]
    serve_kw = {k: kw.pop(k, default) for k, default in
                SERVE_DEFAULTS.items()}
    return _serve_scoped_key(_fleet_key(graphs, target, planner._resolve(kw)),
                             serve_kw)


def _refresh_fleet(fleet: "FleetPlan", cache) -> "FleetPlan":
    """Re-adopt per-tenant calibrated costs on a fleet cache hit.

    ``calibrate.feedback`` parks calibrated plans in the cache under the
    per-tenant keys AFTER the fleet was first planned; a hit must pick those
    up (and re-derive each budget with the tenant's original headroom
    factor) or serving a cached fleet would silently drop the autotune loop.
    """
    tenants = []
    changed = False
    for tp in fleet.tenants:
        plan = _cached_or(tp.plan, cache)
        if plan == tp.plan:
            tenants.append(tp)
            continue
        changed = True
        planned = tp.plan.est_latency_s + tp.crossing_s
        factor = tp.latency_budget_s / planned if planned > 0 \
            else DEFAULT_BUDGET_FACTOR
        tenants.append(dataclasses.replace(
            tp, plan=plan,
            latency_budget_s=factor * (plan.est_latency_s + tp.crossing_s)))
    if not changed:
        return fleet
    return dataclasses.replace(
        fleet, tenants=tuple(tenants),
        est_latency_s=max(t.total_latency_s for t in tenants))


def _net_ids(graphs) -> list[str]:
    """Unique tenant ids (duplicate nets get an #index suffix)."""
    seen: dict[str, int] = {}
    out = []
    for g in graphs:
        n = seen.get(g.name, 0)
        seen[g.name] = n + 1
        out.append(g.name if n == 0 else f"{g.name}#{n}")
    return out


def _cached_or(plan: DeploymentPlan, cache) -> DeploymentPlan:
    """Adopt calibrated COSTS from the cache under the same per-tenant key
    (that is where ``calibrate.feedback`` parks measured latencies), while
    keeping the freshly-computed serve POLICY: the serve knobs
    (``serve_slots_total``/``prefill_chunk``) are not part of the fleet key,
    so a cache hit must not override what this call asked for.  Tiles and
    regimes are identical by keying either way."""
    hit = cache.get(plan.key)
    if hit is None:
        return plan
    serve = dict(plan.serve)
    if "calibration" in hit.serve:
        serve["calibration"] = hit.serve["calibration"]
    return dataclasses.replace(hit, serve=serve)


def _with_slo(serve: dict, kind: str, budget_s: float) -> dict:
    """The tail contract + priority class, written into the plan's serve
    section so the runtime (:class:`repro.obs.slo.SloMonitor`,
    :class:`repro.serve.Router`) needs no side channel: p95 at the
    mean-style latency budget (``budget_factor x (planned + crossing)``),
    p99 at 1.5x that — the headroom a nearest-rank p99 needs over p95 under
    the planner's own jitter model.  Edge tenants default ``critical`` (the
    trigger path the paper's fixed-latency budgets are about), LM tenants
    ``standard``.

    The ``resilience`` block (plan-6) carries the supervisor's per-tenant
    knobs — circuit-breaker K/cooldown, retry budget, deadline factor
    (:data:`repro.faults.RESILIENCE_DEFAULTS`) — so fault-tolerance policy
    ships IN the plan artifact like every other serve policy."""
    return {
        **serve,
        "priority": "standard" if kind == "lm" else "critical",
        "slo": {"p95_s": budget_s, "p99_s": 1.5 * budget_s},
        "resilience": dict(RESILIENCE_DEFAULTS),
    }


def _plan_fleet_aie(graphs, ids, *, key: str, budget_factor: float,
                    cache, opts: dict) -> FleetPlan:
    pl, aie = opts["pl"], opts["aie"]
    preps = [planner._aie_prepare(g, pl_budget=opts["pl_budget"], pl=pl,
                                  aie=aie) for g in graphs]

    # Joint column resolution: all nets' AIE layers in one pool, keyed by
    # (tenant, layer) so band assignment walks tenants in placement order.
    cands = {(ti, li): c
             for ti, p in enumerate(preps) for li, c in p.cands.items()}
    chosen = {k: c[0] for k, c in cands.items()}
    bands = planner._resolve_columns(chosen, cands, aie)
    n_band2 = sum(1 for b in bands.values() if b > 1)

    tenants: list[TenantPlan] = []
    col = 0
    for ti, (g, prep, net_id) in enumerate(zip(graphs, preps, ids)):
        t_chosen = {li: chosen[(ti, li)] for li in prep.cands}
        t_bands = {li: bands[(ti, li)] for li in prep.cands}
        layers = planner._aie_layers(g, prep, t_chosen, t_bands, n_band2,
                                     aie=aie)
        bounds, est_latency, est_interval = planner._aie_totals(g, layers, aie)
        plan = DeploymentPlan(
            network=g.name, target="aie", batch=g.batch,
            key=f"{key}:{net_id}",
            layers=tuple(layers), boundaries=tuple(bounds),
            est_latency_s=est_latency, est_interval_s=est_interval,
            serve={"quantize_weights": True, "prefill_chunk": None},
            kind=g.kind)
        plan = _cached_or(plan, cache)
        # DR7 at the net boundary: the net's result streams off-array through
        # the PLIO fabric shared by every co-resident tenant.
        last = g.nodes[-1]
        crossing = boundary.crossing_cost_aie(
            last.out_bytes(g.batch), plan.est_latency_s, aie=aie)
        cols_used = _band1_cols(plan)
        budget = budget_factor * (plan.est_latency_s + crossing)
        plan = dataclasses.replace(plan, serve=_with_slo(plan.serve, g.kind,
                                                         budget))
        tenants.append(TenantPlan(
            net_id=net_id, plan=plan, col_offset=col, cols=cols_used,
            crossing_s=crossing,
            latency_budget_s=budget))
        col += cols_used

    est = max(t.total_latency_s for t in tenants)
    name = "+".join(ids)
    return FleetPlan(name=name, target="aie", key=key,
                     tenants=tuple(tenants), est_latency_s=est)


def _plan_fleet_tpu(graphs, ids, *, key: str, budget_factor: float,
                    serve_slots_total: int, prefill_chunk: int | None,
                    queue_depth_factor: int, cache, opts: dict) -> FleetPlan:
    tpu = opts["tpu"]
    n_lm = sum(1 for g in graphs if g.kind == "lm") or 1
    tenants: list[TenantPlan] = []
    for g, net_id in zip(graphs, ids):
        plan = planner._plan_tpu(
            g, pipeline_core_budget=opts["pipeline_core_budget"], tpu=tpu,
            key=f"{key}:{net_id}")
        serve = dict(plan.serve)
        if g.kind == "lm":
            # The continuous batcher reads its policy from here (instead of
            # the old hard-coded constants): a fair slot share across LM
            # tenants, plan-chosen chunked-prefill size, one admission per
            # tick so a burst on one tenant cannot monopolize a step.  The
            # queue-depth bound caps how far a tenant's backlog may grow
            # before the router refuses admits (queue-depth-aware admission):
            # waiting behind more than ``factor`` full slot generations
            # cannot land within any budget derived from the planned latency.
            slots = max(1, serve_slots_total // n_lm)
            serve.update({
                "slots": slots,
                "prefill_chunk": prefill_chunk,
                "admit_per_tick": 1,
                "max_queue_depth": max(1, queue_depth_factor * slots),
            })
        plan = _cached_or(dataclasses.replace(plan, serve=serve), cache)
        crossing = boundary.crossing_cost_tpu(g.nodes[-1].out_bytes(g.batch),
                                              tpu)
        budget = budget_factor * (plan.est_latency_s + crossing)
        plan = dataclasses.replace(plan, serve=_with_slo(plan.serve, g.kind,
                                                         budget))
        tenants.append(TenantPlan(
            net_id=net_id, plan=plan, col_offset=0, cols=0,
            crossing_s=crossing,
            latency_budget_s=budget))
    est = max(t.total_latency_s for t in tenants)
    return FleetPlan(name="+".join(ids), target="tpu", key=key,
                     tenants=tuple(tenants), est_latency_s=est)


def plan_fleet(cfgs, *, target: str = "tpu", batch: int | None = None,
               budget_factor: float = SERVE_DEFAULTS["budget_factor"],
               serve_slots_total: int = SERVE_DEFAULTS["serve_slots_total"],
               prefill_chunk: int | None = SERVE_DEFAULTS["prefill_chunk"],
               queue_depth_factor: int = SERVE_DEFAULTS["queue_depth_factor"],
               cache=None, **kw) -> FleetPlan:
    """Place N networks on one array/chip.  ``cfgs`` are EdgeConfigs,
    ModelConfigs or pre-built graphs; planner knobs (``pl_budget``,
    ``pipeline_core_budget``, ``pl``/``aie``/``tpu``, and ``machine_model``
    — a fitted :class:`repro.characterize.MachineModel` replacing the
    hand-tuned constants) pass through ``kw``.

    The whole fleet is cached: a repeat call with the same nets, hardware,
    planner AND serve knobs returns the cached :class:`FleetPlan`
    (re-adopting any per-tenant calibration written since).  Per-tenant
    plans are additionally looked up in ``cache`` (the process-wide default
    cache unless given) under their fleet-scoped keys before the fresh plan
    is used, which closes the autotune loop: measured latencies written back
    by ``calibrate.feedback`` / ``EdgeEngine.record_calibration`` are picked
    up by the next ``plan_fleet`` of the same fleet.
    """
    if not cfgs:
        raise ValueError("plan_fleet needs at least one network")
    graphs = [planner.as_graph(c, batch=batch) for c in cfgs]
    ids = _net_ids(graphs)
    opts = planner._resolve(kw)
    serve_kw = {"budget_factor": budget_factor,
                "serve_slots_total": serve_slots_total,
                "prefill_chunk": prefill_chunk,
                "queue_depth_factor": queue_depth_factor}
    key = _fleet_key(graphs, target, opts)
    store_key = _serve_scoped_key(key, serve_kw)
    cache = cache if cache is not None else default_cache()
    hit = cache.get_fleet(store_key)
    if hit is not None:
        return _refresh_fleet(hit, cache)
    if target == "aie":
        fleet = _plan_fleet_aie(graphs, ids, key=key,
                                budget_factor=budget_factor, cache=cache,
                                opts=opts)
    elif target == "tpu":
        fleet = _plan_fleet_tpu(graphs, ids, key=key,
                                budget_factor=budget_factor,
                                serve_slots_total=serve_slots_total,
                                prefill_chunk=prefill_chunk,
                                queue_depth_factor=queue_depth_factor,
                                cache=cache, opts=opts)
    else:
        raise ValueError(f"unknown target {target!r} (want 'aie' or 'tpu')")
    return cache.put_fleet(fleet, key=store_key)
