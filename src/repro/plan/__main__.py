"""Planner CLI.

  PYTHONPATH=src python -m repro.plan jet_tagger
  PYTHONPATH=src python -m repro.plan all --target both --out plans/
  PYTHONPATH=src python -m repro.plan qwen2_5_3b --kind lm --target tpu
  PYTHONPATH=src python -m repro.plan jet_tagger tau_select --target aie

Prints a per-layer plan table and writes the DeploymentPlan JSON artifact
(``<out>/<net>_<target>.json``).  Naming MORE THAN ONE net plans them as a
co-resident fleet (joint column packing, paper Section V-C) and writes a
``FleetPlan`` artifact (``<out>/fleet_<n1>+<n2>_<target>.json``).
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from repro.plan import artifact, multinet, planner


def _print_plan(plan: artifact.DeploymentPlan) -> None:
    print(f"\n# {plan.network} [{plan.target}]  batch={plan.batch}  "
          f"key={plan.key[:12]}…")
    hdr = (f"{'layer':<10}{'shape':>12}  {'regime':<9}{'LARE':>8}"
           f"{'P_KxP_N':>9}{'band':>5}  {'tile':<16}{'interval':>11}")
    print(hdr)
    for l in plan.layers:
        rep = f" x{l.repeat}" if l.repeat > 1 else ""
        print(f"{l.name:<10}{f'{l.n_in}->{l.n_out}{rep}':>12}  "
              f"{l.regime:<9}{l.lare:>8.1f}{f'{l.p_k}x{l.p_n}':>9}"
              f"{l.band:>5}  {str(l.api_tile):<16}"
              f"{l.est_interval_s * 1e6:>9.2f}us")
    for b in plan.boundaries:
        print(f"  boundary after layer {b.after_layer}: "
              f"{b.from_regime}->{b.to_regime} "
              f"(+{b.crossing_s * 1e6:.2f}us)")
    print(f"totals: latency={plan.est_latency_s * 1e6:.2f}us  "
          f"interval={plan.est_interval_s * 1e6:.2f}us  "
          f"rate={plan.inferences_per_s / 1e6:.2f} MHz")


def _print_fleet(fleet: multinet.FleetPlan) -> None:
    print(f"\n# fleet {fleet.name} [{fleet.target}]  "
          f"key={fleet.key[:12]}…  band1_cols={fleet.band1_cols_used}")
    print(f"{'tenant':<14}{'cols':>10}  {'planned':>11}{'+cross':>10}"
          f"{'budget':>11}")
    for t in fleet.tenants:
        cols = (f"{t.col_offset}..{t.col_offset + t.cols - 1}"
                if t.cols else "-")
        print(f"{t.net_id:<14}{cols:>10}  "
              f"{t.plan.est_latency_s * 1e6:>9.2f}us"
              f"{t.crossing_s * 1e6:>8.2f}us"
              f"{t.latency_budget_s * 1e6:>9.2f}us")
    for t in fleet.tenants:
        _print_plan(t.plan)


def main(argv: list[str] | None = None) -> int:
    from repro.models import edge

    ap = argparse.ArgumentParser(prog="python -m repro.plan",
                                 description=__doc__)
    ap.add_argument("net", nargs="+",
                    help="edge net name (see EDGE_NETS), an LM arch id with "
                         "--kind lm, or 'all'; several names plan a "
                         "co-resident fleet")
    ap.add_argument("--target", choices=("aie", "tpu", "both"),
                    default="both")
    ap.add_argument("--kind", choices=("edge", "lm"), default="edge")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--pl-budget", type=float, default=400.0,
                    help="PL DSP-equivalents per layer for the LARE decision")
    ap.add_argument("--machine-model", default=None, metavar="MODEL_JSON",
                    help="fitted MachineModel artifact (python -m "
                         "repro.characterize) replacing the hand-tuned "
                         "hw.py constants")
    ap.add_argument("--out", default="plans",
                    help="directory for the JSON artifacts")
    args = ap.parse_args(argv)

    machine_model = None
    if args.machine_model is not None:
        from repro.characterize import MachineModel
        machine_model = MachineModel.load(args.machine_model)
        print(f"# machine model {machine_model.version[:12]}… "
              f"(sweep={machine_model.provenance.get('sweep')}, "
              f"host={machine_model.provenance.get('host')})")

    if args.kind == "lm":
        from repro import configs
        cfgs = [configs.get(n).config for n in args.net]
    elif args.net == ["all"]:
        cfgs = [edge.edge_config(n) for n in edge.EDGE_NETS]
    else:
        for n in args.net:
            if n not in edge.EDGE_NETS:
                print(f"unknown net {n!r}; choose from "
                      f"{sorted(edge.EDGE_NETS)} or 'all'", file=sys.stderr)
                return 2
        cfgs = [edge.edge_config(n) for n in args.net]

    targets = ("aie", "tpu") if args.target == "both" else (args.target,)
    if args.kind == "lm":
        targets = tuple(t for t in targets if t == "tpu") or ("tpu",)
    out_dir = pathlib.Path(args.out)

    # Several nets named explicitly: plan them as one co-resident fleet.
    if len(args.net) > 1 and args.net != ["all"]:
        for target in targets:
            fleet = multinet.plan_fleet(cfgs, target=target,
                                        batch=args.batch,
                                        pl_budget=args.pl_budget,
                                        machine_model=machine_model)
            _print_fleet(fleet)
            path = fleet.save(out_dir / f"fleet_{fleet.name}_{target}.json")
            print(f"wrote {path}")
        return 0

    for cfg in cfgs:
        for target in targets:
            plan = planner.plan_deployment(cfg, target=target,
                                           batch=args.batch,
                                           pl_budget=args.pl_budget,
                                           machine_model=machine_model)
            _print_plan(plan)
            name = getattr(cfg, "name", plan.network)
            path = plan.save(out_dir / f"{name}_{target}.json")
            print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
