"""Planner CLI — DEPRECATED shim over ``python -m repro plan``.

  PYTHONPATH=src python -m repro.plan jet_tagger
  PYTHONPATH=src python -m repro.plan all --target both --out plans/
  PYTHONPATH=src python -m repro.plan qwen2_5_3b --kind lm --target tpu
  PYTHONPATH=src python -m repro.plan jet_tagger tau_select --target aie

Same flags, same artifacts, same tables — the implementation moved to the
unified CLI (:mod:`repro.cli`), which routes through the staged deployment
facade (:mod:`repro.deploy`).  Prefer ``python -m repro plan ...``.
"""

from __future__ import annotations

import sys


def main(argv: list[str] | None = None) -> int:
    from repro.cli import deprecated_main
    return deprecated_main("repro.plan", "plan", argv)


if __name__ == "__main__":
    sys.exit(main())
