"""The deployment planner: compose LARE (Alg. 1), two-level tiling (Alg. 2),
column-exhaustion/band constraints (Figs. 5/6) and boundary-crossing costs
(DR7) into one decision procedure over a :class:`~repro.plan.graph.DataflowGraph`.

Two targets:

* ``target="aie"`` — the paper-faithful path.  Every layer runs LARE and is
  assigned PL (spatial dataflow at the cheapest reuse factor that fits the
  budget) or AIE (spatial ``P_K x P_N`` tiling + best ``aie::mmul`` API
  shape).  AIE layers then compete for array columns: when the summed ``P_K``
  exhausts ``usable_cols`` the planner first tries to *shrink* the split
  whose interval suffers least, and only spills into a second band when
  shrinking costs more than the Fig.-6 contention penalty.  PL<->AIE
  transitions are charged the Fig.-7 crossing cost.

* ``target="tpu"`` — the executable path.  LARE's TPU analogue
  (:func:`repro.core.lare.lare_tpu`) decides pipelined-cores vs tiled-Pallas
  per layer; API-level tiles come from :func:`repro.core.tiling.plan_api`
  (these are the Pallas block shapes ``models/edge.py`` executes); launches
  are grouped by the DR7' fusion DP and every group boundary is charged the
  HBM-round-trip + dispatch cost.

Both emit the same :class:`~repro.plan.artifact.DeploymentPlan` schema.
"""

from __future__ import annotations

import dataclasses
import math

from repro import hw as hwlib
from repro.core import boundary, lare, tiling
from repro.plan.artifact import (BoundaryPlan, DeploymentPlan, FusionGroup,
                                 LayerPlan, default_cache, plan_key)
from repro.plan.graph import DataflowGraph, edge_graph, model_graph

# Per-layer spatial split candidates on the AIE array (paper Fig. 5 sweep).
_AIE_SPLITS = (1, 2, 3, 4, 6, 8)
_AIE_MAX_TILES_PER_LAYER = 12


def as_graph(cfg, *, batch: int | None = None) -> DataflowGraph:
    """Accept an EdgeConfig, a ModelConfig, or an already-built graph."""
    if isinstance(cfg, DataflowGraph):
        return cfg
    if hasattr(cfg, "layer_shapes") and hasattr(cfg, "dims"):
        return edge_graph(cfg)
    if hasattr(cfg, "family"):
        return model_graph(cfg, batch=batch or 1)
    raise TypeError(f"cannot build a dataflow graph from {type(cfg)!r}")


# ---------------------------------------------------------------------------
# AIE path (paper-faithful)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _AieChoice:
    """One (P_K, P_N, api tile) candidate for a layer, pre-penalty."""
    interval_s: float
    latency_s: float
    p_k: int
    p_n: int
    s: tuple[int, int, int]


def _aie_candidates(batch: int, n_in: int, n_out: int,
                    aie: hwlib.AieMl) -> list[_AieChoice]:
    """Legal split candidates sorted fastest-first (DR3/DR5 constraints)."""
    out: list[_AieChoice] = []
    for p_k in _AIE_SPLITS:
        for p_n in _AIE_SPLITS:
            if p_k * p_n > _AIE_MAX_TILES_PER_LAYER or p_n > aie.rows \
                    or p_k > aie.usable_cols:
                continue
            q_k, q_n = math.ceil(n_in / p_k), math.ceil(n_out / p_n)
            # DR5: floors on the dims being split.
            if (p_k > 1 and q_k < 16) or (p_n > 1 and q_n < 32):
                continue
            best_s, best_i = None, float("inf")
            for s in aie.legal_api_tiles_i8:
                t = tiling.aie_tile_interval(batch, q_k, q_n, s, aie)
                if t < best_i:
                    best_s, best_i = s, t
            assert best_s is not None
            out.append(_AieChoice(
                interval_s=tiling.aie_spatial_interval(
                    batch, n_in, n_out, p_k, p_n, best_s, aie=aie),
                latency_s=tiling.aie_spatial_latency(
                    batch, n_in, n_out, p_k, p_n, best_s, aie=aie),
                p_k=p_k, p_n=p_n, s=best_s))
    out.sort(key=lambda c: (c.interval_s, c.p_k * c.p_n))
    return out


def _resolve_columns(chosen: dict, cands: dict,
                     aie: hwlib.AieMl) -> dict:
    """Column-exhaustion resolution: shrink cheap splits until the summed
    ``P_K`` fits one band, unless shrinking costs more than spilling
    (Fig. 6).  Returns {layer key: band} and mutates ``chosen``.

    Keys only need to sort stably (ints for a single net; ``(tenant, layer)``
    tuples when the fleet packer pools several nets' layers into one joint
    resolution), so co-resident networks compete for the same columns under
    the same shrink-vs-spill rule."""

    def cols() -> int:
        return sum(c.p_k for c in chosen.values())

    spill_interval = _spilled_worst_interval(chosen, aie)
    while cols() > aie.usable_cols:
        # Cheapest single-layer shrink that reduces column usage.
        best_li, best_alt, best_cost = None, None, float("inf")
        for li, cur in chosen.items():
            for alt in cands[li]:
                if alt.p_k < cur.p_k:
                    cost = alt.interval_s - cur.interval_s
                    if cost < best_cost:
                        best_li, best_alt, best_cost = li, alt, cost
                    break            # candidates are sorted; first is cheapest
        if best_li is None:
            break                    # nothing shrinkable: must spill
        # Worst interval if we shrink vs worst interval if we stop and spill.
        trial = dict(chosen)
        trial[best_li] = best_alt
        shrink_worst = max(c.interval_s for c in trial.values())
        if shrink_worst > spill_interval:
            break                    # DR6: the band-2 penalty is cheaper
        chosen[best_li] = best_alt
    # Assign bands first-fit in layer order: only band-1 residents consume
    # band-1 columns, so one oversized layer spilling does not cascade every
    # later layer (or, fleet-wide, every later tenant) into band 2 while
    # band-1 columns sit free.
    bands: dict[int, int] = {}
    col = 0
    for li in sorted(chosen):
        c = chosen[li]
        if col + c.p_k <= aie.usable_cols:
            bands[li] = 1
            col += c.p_k
        else:
            bands[li] = 2
    return bands


def _spilled_worst_interval(chosen: dict, aie: hwlib.AieMl) -> float:
    """Worst-layer interval if the current overflow goes to band 2 as-is
    (same first-fit band rule as the final assignment)."""
    spilled = []
    col = 0
    for li in sorted(chosen):
        if col + chosen[li].p_k <= aie.usable_cols:
            col += chosen[li].p_k
        else:
            spilled.append(li)
    worst = 0.0
    penalty = 1.0 + aie.band2_penalty_per_layer * len(spilled)
    for li in sorted(chosen):
        t = chosen[li].interval_s * (penalty if li in spilled else 1.0)
        worst = max(worst, t)
    return worst


@dataclasses.dataclass
class _AiePrep:
    """Per-graph LARE decisions + PL picks + AIE candidate lists — everything
    the column allocator needs, before any columns are committed.  Shared by
    the single-net path and the multi-network fleet packer
    (:mod:`repro.plan.multinet`), which pools several preps' candidates into
    one joint :func:`_resolve_columns` call."""
    lares: dict[int, lare.LareResult]
    regimes: dict[int, str]
    pl_plans: dict[int, tuple[int, float, float]]   # i -> (rf, ival, lat)
    cands: dict[int, list[_AieChoice]]


def _aie_prepare(graph: DataflowGraph, *, pl_budget: float,
                 pl: hwlib.PlFabric, aie: hwlib.AieMl) -> _AiePrep:
    batch = graph.batch
    lares = {n.index: lare.lare(n.n_in, n.n_out, batch=batch, pl=pl, aie=aie)
             for n in graph}
    regimes = {i: r.decide(pl_budget) for i, r in lares.items()}

    # PL layers: cheapest interval whose resources fit the budget.
    pl_plans: dict[int, tuple[int, float, float]] = {}
    for node in graph:
        if regimes[node.index] != "pl":
            continue
        pick = None
        for rf in pl.legal_reuse_factors(node.n_in, node.n_out):
            res = pl.resources(node.n_in, node.n_out, rf)
            if pl.fits(res) and pl.resource_scalar(res) <= pl_budget:
                pick = rf
                break                                   # rfs ascend: min II
        if pick is None:        # budget can't actually host it: send to AIE
            regimes[node.index] = "aie"
            continue
        pl_plans[node.index] = (pick, pl.interval_s(pick),
                                pl.latency_s(node.n_in, node.n_out, pick,
                                             batch))

    cands = {n.index: _aie_candidates(batch, n.n_in, n.n_out, aie)
             for n in graph if regimes[n.index] == "aie"}
    return _AiePrep(lares=lares, regimes=regimes, pl_plans=pl_plans,
                    cands=cands)


def _aie_layers(graph: DataflowGraph, prep: _AiePrep,
                chosen: dict[int, _AieChoice], bands: dict[int, int],
                n_band2: int, *,
                aie: hwlib.AieMl = hwlib.AIE_ML) -> list[LayerPlan]:
    """Materialize LayerPlans from resolved choices.  ``n_band2`` is the
    band-2 population of the WHOLE array (fleet-wide under co-residency), so
    contention is priced against every spilled layer, not just this net's."""
    layers: list[LayerPlan] = []
    for node in graph:
        i = node.index
        rules: list[str] = []
        if prep.regimes[i] == "pl":
            rf, ival, lat = prep.pl_plans[i]
            rules.append(
                f"LARE={prep.lares[i].lare:.1f}<=budget -> PL(rf={rf})")
            layers.append(LayerPlan(
                index=i, name=node.name, n_in=node.n_in, n_out=node.n_out,
                regime="pl", lare=prep.lares[i].lare, p_k=1, p_n=1, band=0,
                api_tile=(0, 0, 0), fuse_group=i, est_latency_s=lat,
                est_interval_s=ival, act=node.act, repeat=node.repeat,
                rules=tuple(rules)))
            continue
        c, band = chosen[i], bands[i]
        penalty = (1.0 + aie.band2_penalty_per_layer * n_band2) \
            if band > 1 else 1.0
        rules.append(f"LARE={prep.lares[i].lare:.1f}>budget -> AIE")
        if c.p_k > 1:
            rules.append(f"DR3(K-expansion P_K={c.p_k})")
        rules.append(f"DR1(api={c.s})")
        if band > 1:
            rules.append(f"DR6(band-2 spill, {n_band2} layers)")
        layers.append(LayerPlan(
            index=i, name=node.name, n_in=node.n_in, n_out=node.n_out,
            regime="aie", lare=prep.lares[i].lare, p_k=c.p_k, p_n=c.p_n,
            band=band, api_tile=c.s, fuse_group=i,
            est_latency_s=c.latency_s * penalty,
            est_interval_s=c.interval_s * penalty, act=node.act,
            repeat=node.repeat, rules=tuple(rules)))
    return layers


def _aie_totals(graph: DataflowGraph, layers: list[LayerPlan],
                aie: hwlib.AieMl
                ) -> tuple[list[BoundaryPlan], float, float]:
    """Boundary charges at every PL<->AIE transition (DR7 / Fig. 7) and the
    resulting latency/interval totals."""
    batch = graph.batch
    base_latency = sum(l.est_latency_s for l in layers)
    boundaries: list[BoundaryPlan] = []
    for prev, nxt in zip(layers, layers[1:]):
        if prev.regime != nxt.regime:
            boundaries.append(BoundaryPlan(
                after_layer=prev.index, from_regime=prev.regime,
                to_regime=nxt.regime,
                crossing_s=boundary.crossing_cost_aie(
                    graph.nodes[prev.index].out_bytes(batch), base_latency,
                    aie=aie)))
    est_latency = base_latency + sum(b.crossing_s for b in boundaries)
    est_interval = max(l.est_interval_s for l in layers)
    return boundaries, est_latency, est_interval


def _plan_aie(graph: DataflowGraph, *, pl_budget: float,
              pl: hwlib.PlFabric, aie: hwlib.AieMl,
              key: str) -> DeploymentPlan:
    prep = _aie_prepare(graph, pl_budget=pl_budget, pl=pl, aie=aie)
    chosen = {i: c[0] for i, c in prep.cands.items()}
    bands = _resolve_columns(chosen, prep.cands, aie)
    n_band2 = sum(1 for b in bands.values() if b > 1)
    layers = _aie_layers(graph, prep, chosen, bands, n_band2, aie=aie)
    boundaries, est_latency, est_interval = _aie_totals(graph, layers, aie)
    return DeploymentPlan(
        network=graph.name, target="aie", batch=graph.batch, key=key,
        layers=tuple(layers), boundaries=tuple(boundaries),
        est_latency_s=est_latency, est_interval_s=est_interval,
        serve={"quantize_weights": True, "prefill_chunk": None},
        kind=graph.kind)


# ---------------------------------------------------------------------------
# TPU path (executable)
# ---------------------------------------------------------------------------

def _plan_tpu(graph: DataflowGraph, *, pipeline_core_budget: int,
              tpu: hwlib.TpuV5e, key: str) -> DeploymentPlan:
    batch = graph.batch
    layers: list[LayerPlan] = []
    stages: list[boundary.Stage] = []
    quantize = False
    # The megakernel is not grid-blocked: it computes on ceil8(batch) live
    # rows while the per-layer int8 kernel is pinned to its 32-row block
    # tile.  At the paper's batch 8 that is 4x less GEMM work per fused
    # layer — priced here so the fuse-vs-split DP sees the real trade-off.
    row_trim = min(1.0, math.ceil(batch / 8) * 8
                   / (math.ceil(batch / 32) * 32))
    for node in graph:
        itemsize = node.itemsize
        rt = lare.lare_tpu(node.n_in, node.n_out, batch=batch,
                           itemsize=itemsize, tpu=tpu,
                           max_cores=max(pipeline_core_budget, 1))
        regime = rt.decide(pipeline_core_budget)
        # inf == "no pipeline width matches the tiled kernel" — store -1 so
        # the artifact stays strict JSON.
        core_eq = rt.core_eq if math.isfinite(rt.core_eq) else -1.0
        api = tiling.plan_api(batch, node.n_in, node.n_out,
                              itemsize=itemsize, tpu=tpu)
        rules = [f"core_eq={core_eq:.1f} -> {regime}",
                 f"DR1'(block={api.blocks})"]
        if api.block_n >= api.block_k:
            rules.append("DR2'(N-favored)")
        if node.macs >= 1 << 16:
            quantize = True
        layers.append(LayerPlan(
            index=node.index, name=node.name, n_in=node.n_in,
            n_out=node.n_out, regime=regime, lare=core_eq, p_k=1, p_n=1,
            band=1, api_tile=api.blocks, fuse_group=0,
            est_latency_s=api.est_s, est_interval_s=api.est_s,
            act=node.act, repeat=node.repeat, rules=tuple(rules)))
        # Fusion-DP stages carry PURE compute (each group charges its own
        # single launch dispatch in fused_group_cost).
        compute_s = max(api.est_s - tpu.kernel_overhead_s, 0.0)
        stages.append(boundary.Stage(
            name=node.name, compute_s=compute_s,
            fused_compute_s=compute_s * (row_trim if itemsize == 1 else 1.0),
            out_bytes=node.out_bytes(batch), vmem_bytes=api.vmem_bytes))

    # DR7' launch fusion: group layers whose working sets co-reside in VMEM
    # and whose fused epilogue undercuts the un-fused crossing.  The result
    # is EXECUTABLE: each multi-layer group becomes one fused_mlp megakernel
    # launch (kernels/fused_mlp), so the plan charges what the runtime pays.
    groups = boundary.plan_fusion(stages, tpu=tpu)
    # A fused launch executes all members together, so a group must be
    # repeat-uniform (LM graphs mix repeated blocks with one-shot heads) and
    # regime-uniform (a regime transition is itself a charged boundary and
    # must never land INSIDE a group): renumber with a forced break at every
    # repeat or regime change, so every emitted BoundaryPlan sits between
    # groups and no boundary is both fused and crossed.
    renum, g = [0] if layers else [], 0
    for i in range(1, len(layers)):
        if groups[i] != groups[i - 1] \
                or layers[i].repeat != layers[i - 1].repeat \
                or layers[i].regime != layers[i - 1].regime:
            g += 1
        renum.append(g)
    groups = renum
    layers = [dataclasses.replace(l, fuse_group=g,
                                  rules=l.rules + ((f"DR7'(fuse_group={g})",)))
              for l, g in zip(layers, groups)]

    fusion_groups: list[FusionGroup] = []
    for gid in dict.fromkeys(groups):            # stable unique order
        members = [i for i, g in enumerate(groups) if g == gid]
        rep = layers[members[0]].repeat
        group_stages = [stages[i] for i in members]
        group_cost = boundary.fused_group_cost(group_stages, tpu)
        fusion_groups.append(FusionGroup(
            id=gid, layers=tuple(layers[i].index for i in members),
            est_latency_s=group_cost * rep,
            vmem_bytes=sum(stages[i].vmem_bytes for i in members)))
        # Per-layer estimates amortize the group's launch + epilogue costs
        # over its members, so the plan decomposes EXACTLY as
        # sum(layer ests x repeat) + sum(crossings) + entry == est_latency —
        # the invariant calibrate.feedback rescales under.  The base is the
        # compute the group ACTUALLY charges per member (fused compute for
        # multi-layer groups), keeping every share non-negative.
        base = ([s.compute_s for s in group_stages] if len(members) == 1
                else [s.in_group_compute_s for s in group_stages])
        share = (group_cost - sum(base)) / len(members)
        for i, b in zip(members, base):
            est = b + share
            layers[i] = dataclasses.replace(layers[i], est_latency_s=est,
                                            est_interval_s=est)

    boundaries: list[BoundaryPlan] = []
    for prev, nxt in zip(layers, layers[1:]):
        if prev.fuse_group != nxt.fuse_group or prev.regime != nxt.regime:
            # The next group's dispatch is in its own group cost; the
            # boundary itself costs the activation's HBM round trip.
            boundaries.append(BoundaryPlan(
                after_layer=prev.index, from_regime=prev.regime,
                to_regime=nxt.regime,
                crossing_s=2.0 * graph.nodes[prev.index].out_bytes(batch)
                / tpu.hbm_bw))

    est_latency = sum(g.est_latency_s for g in fusion_groups) \
        + sum(b.crossing_s for b in boundaries) \
        + tpu.kernel_overhead_s        # chain-entry host dispatch
    per_layer = [l.est_latency_s * l.repeat for l in layers]
    all_pipeline = all(l.regime == "pipeline" for l in layers)
    est_interval = max(per_layer) if all_pipeline else est_latency
    return DeploymentPlan(
        network=graph.name, target="tpu", batch=batch, key=key,
        layers=tuple(layers), boundaries=tuple(boundaries),
        est_latency_s=est_latency, est_interval_s=est_interval,
        serve={"quantize_weights": quantize, "prefill_chunk": None,
               "decode_regime": ("pipeline" if all_pipeline else "tiled")},
        kind=graph.kind, fusion_groups=tuple(fusion_groups))


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

_DEFAULTS = {
    "pl_budget": 400.0,
    "pipeline_core_budget": 8,
    "pl": hwlib.PL_FABRIC,
    "aie": hwlib.AIE_ML,
    "tpu": hwlib.TPU_V5E,
    # A fitted repro.characterize.MachineModel.  When set it re-parameterizes
    # the tpu/aie models with the fitted constants (overriding explicit tpu=/
    # aie= knobs) and its version is mixed into the plan cache key, so plans
    # made under a stale model self-invalidate.
    "machine_model": None,
}


def _resolve(kw: dict) -> dict:
    """Planner knobs with defaults applied — the single source of truth, so
    the cache key and the search can never disagree."""
    unknown = set(kw) - set(_DEFAULTS)
    if unknown:
        raise TypeError(f"unknown planner option(s): {sorted(unknown)}")
    opts = {**_DEFAULTS, **kw}
    mm = opts["machine_model"]
    if mm is not None:
        opts["tpu"] = mm.tpu(base=opts["tpu"])
        opts["aie"] = mm.aie(base=opts["aie"])
    return opts


def _key_for(graph: DataflowGraph, target: str, opts: dict) -> str:
    mm = opts.get("machine_model")
    mm_version = mm.version if mm is not None else None
    if target == "aie":
        return plan_key(graph, target, (opts["pl"], opts["aie"]),
                        {"pl_budget": opts["pl_budget"],
                         "machine_model": mm_version})
    if target == "tpu":
        return plan_key(graph, target, (opts["tpu"],),
                        {"pipeline_core_budget": opts["pipeline_core_budget"],
                         "machine_model": mm_version})
    raise ValueError(f"unknown target {target!r} (want 'aie' or 'tpu')")


def plan_deployment(cfg, *, target: str = "tpu", batch: int | None = None,
                    **kw) -> DeploymentPlan:
    """Plan one deployment.  ``cfg`` is an EdgeConfig, ModelConfig or graph.

    Keyword knobs (all optional): ``pl_budget``, ``pipeline_core_budget``,
    the hardware models ``pl``/``aie``/``tpu``, and ``machine_model`` — a
    fitted :class:`repro.characterize.MachineModel` whose constants replace
    the hand-tuned ``tpu``/``aie`` ones (and whose version keys the cache).
    """
    graph = as_graph(cfg, batch=batch)
    opts = _resolve(kw)
    key = _key_for(graph, target, opts)
    if target == "aie":
        return _plan_aie(graph, pl_budget=opts["pl_budget"], pl=opts["pl"],
                         aie=opts["aie"], key=key)
    return _plan_tpu(graph,
                     pipeline_core_budget=opts["pipeline_core_budget"],
                     tpu=opts["tpu"], key=key)


def get_or_plan(cfg, *, target: str = "tpu", cache=None, **kw) -> DeploymentPlan:
    """Cache-aware :func:`plan_deployment` (the consumers' entry point)."""
    cache = cache if cache is not None else default_cache()
    graph = as_graph(cfg, batch=kw.pop("batch", None))
    key = _key_for(graph, target, _resolve(kw))
    hit = cache.get(key)
    if hit is not None:
        return hit
    return cache.put(plan_deployment(graph, target=target, **kw))
