"""Logical-axis sharding rules engine (MaxText-style).

Model code annotates values with *logical* axis names
(``shard(x, "batch", "seq", "embed")``); a rule set active in context maps
logical names to mesh axes and applies ``with_sharding_constraint``.  With no
context active (single-device smoke tests) every annotation is a no-op, so
the same model code runs everywhere.

Rule sets are per-regime: training wants FSDP+TP (+SP on the residual
stream); serving wants pure TP with batch over data; the extreme-edge path
wants everything replicated but the layer's own spatial plan.  The paper's
*spatial level* of Algorithm 2 enters here: ``core.tiling.plan_spatial``
decides whether a layer's K or N dimension is sharded, and the rules carry
that decision onto the mesh.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    rules: Mapping[str, tuple[str, ...] | str | None]

    def spec(self, *logical: str | None) -> P:
        axes = []
        used: set[str] = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            mapped = self.rules.get(name)
            if mapped is None:
                axes.append(None)
                continue
            mapped_t = (mapped,) if isinstance(mapped, str) else tuple(mapped)
            # An axis may appear at most once in a PartitionSpec.
            mapped_t = tuple(a for a in mapped_t if a not in used
                             and a in self.mesh.axis_names)
            used.update(mapped_t)
            if not mapped_t:
                axes.append(None)
            elif len(mapped_t) == 1:
                axes.append(mapped_t[0])
            else:
                axes.append(mapped_t)
        return P(*axes)

    def sharding(self, *logical: str | None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_CTX: contextvars.ContextVar[ShardCtx | None] = contextvars.ContextVar(
    "repro_shard_ctx", default=None)


def current() -> ShardCtx | None:
    return _CTX.get()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Mapping[str, tuple[str, ...] | str | None]):
    tok = _CTX.set(ShardCtx(mesh, dict(rules)))
    try:
        yield _CTX.get()
    finally:
        _CTX.reset(tok)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate `x` with the mapped PartitionSpec (no-op without context).

    Dims whose size is not divisible by the mapped axis product silently drop
    the constraint (e.g. kv_heads=1 cannot shard over a 16-way model axis) —
    this keeps one model definition valid across every arch x mesh cell.
    """
    ctx = current()
    if ctx is None:
        return x
    spec_ = ctx.spec(*logical)
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    fixed = []
    for dim, entry in zip(x.shape, tuple(spec_) + (None,) * (x.ndim - len(spec_))):
        if entry is None:
            fixed.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        prod = 1
        kept = []
        for a in axes:
            if dim % (prod * sizes[a]) == 0:
                kept.append(a)
                prod *= sizes[a]
        fixed.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fixed)))


def spec(*logical: str | None) -> P:
    ctx = current()
    if ctx is None:
        return P()
    return ctx.spec(*logical)


# ---------------------------------------------------------------------------
# Canonical rule sets
# ---------------------------------------------------------------------------

def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """All data-parallel axes present in the mesh ('pod' folds into DP)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def train_rules(mesh: Mesh, *, fsdp: bool = True,
                seq_shard: bool = True) -> dict:
    """FSDP over data + TP over model (+ SP on the residual stream)."""
    dp = dp_axes(mesh)
    return {
        "batch": dp,
        "seq": "model" if seq_shard else None,   # sequence/"activation" parallel
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "lru": "model",
        # weight FSDP axes (the dim opposite the TP dim)
        "fsdp": dp if fsdp else None,
        # optimizer-state sharding (ZeRO-1) uses the same fsdp axes
        "zero": dp,
    }


def serve_rules(mesh: Mesh, *, seq_shard: bool = False) -> dict:
    """Pure TP; batch over data; no FSDP (weights replicated over data).

    ``seq_shard=True`` enables sequence-parallel serving (§Perf): the
    residual stream shards over ``model`` on the sequence dim, so prefill
    attention/MLP for narrow-head archs stops replicating activations over
    the model axis (GSPMD otherwise auto-splits the attention contraction
    and pays an all-reduce per KV chunk — measured 479 GB on gemma2-2b
    prefill).  Decode (seq=1) drops the constraint automatically."""
    dp = dp_axes(mesh)
    return {
        "batch": dp,
        "seq": "model" if seq_shard else None,
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "lru": "model",
        "fsdp": None,
        "zero": None,
    }


def edge_rules(mesh: Mesh) -> dict:
    """Extreme-edge low-latency path: replicate, let the tiling plan decide."""
    return {k: None for k in ("batch", "seq", "embed", "heads", "kv_heads",
                              "mlp", "vocab", "expert", "lru", "fsdp", "zero")}
