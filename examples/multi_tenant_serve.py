"""Multi-tenant serving example: co-resident networks behind one router.

  PYTHONPATH=src python examples/multi_tenant_serve.py

Plans two extreme-edge nets AND a small LM as one fleet (joint placement,
per-tenant latency budgets derived from the plan), builds a router over
them, and drives mixed traffic: synchronous edge inferences interleaved
with continuous-batched LM requests.  Ends with the per-tenant metrics
report and writes the measured edge latencies back into the plan cache
(the autotune feedback loop).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api, edge
from repro.plan import calibrated_cpu_model, plan_fleet
from repro.serve import Router, engine


def main():
    edge_cfgs = [edge.edge_config("jet_tagger"), edge.edge_config("tau_select")]
    lm_cfg = configs.get("qwen2_5_3b").smoke
    lm_params = api.init(lm_cfg, jax.random.PRNGKey(0))

    # One fleet: two edge tenants + one LM tenant, planned with the machine
    # model calibrated to THIS host so budgets are meaningful.
    fleet = plan_fleet(edge_cfgs + [lm_cfg], target="tpu",
                       tpu=calibrated_cpu_model(),
                       serve_slots_total=3, prefill_chunk=4)
    lm_id = fleet.net_ids[-1]
    print(f"fleet {fleet.name}:")
    for t in fleet.tenants:
        print(f"  {t.net_id:<14} kind={t.plan.kind:<5} "
              f"planned={t.plan.est_latency_s * 1e6:8.1f}us "
              f"budget={t.latency_budget_s * 1e6:8.1f}us")

    router = Router.from_fleet(fleet, lm={lm_id: (lm_cfg, lm_params)})

    # Warm up the edge engines (jit compile) so the report shows
    # steady-state latencies, then zero the counters.
    xs = {c.name: jnp.ones((c.batch, c.dims[0]), jnp.float32)
          for c in edge_cfgs}
    for name, x in xs.items():
        router.infer(name, x)
        router.tenant(name).engine.reset_measurements()
    router.reset_metrics()

    # Mixed traffic: submit LM requests, then interleave edge inferences
    # with batcher ticks (the LM tenant decodes while edge nets serve).
    rng = np.random.default_rng(0)
    reqs = [engine.Request(rid=i,
                           prompt=rng.integers(1, lm_cfg.vocab_size,
                                               3).astype(np.int32),
                           max_new=4)
            for i in range(4)]
    for r in reqs:
        router.submit(lm_id, r)
    for tick in range(40):
        for name, x in xs.items():
            router.infer(name, x)
        if router.step() == 0 and all(r.done for r in reqs):
            break

    print("\nper-tenant report:")
    for nid, m in router.report().items():
        print(f"  {nid:<14} n={m['count']:<3} mean={m['mean_s'] * 1e6:8.1f}us "
              f"p95={m['p95_s'] * 1e6:8.1f}us "
              f"violations={m['budget_violations']} "
              f"occupancy={m['occupancy']:.2f}")
    assert all(r.done for r in reqs)
    for r in reqs:
        print(f"  lm req {r.rid}: {len(r.out)} tokens")

    # Autotune feedback: measured edge latencies land in the plan cache.
    for c in edge_cfgs:
        cal = router.tenant(c.name).engine.record_calibration()
        print(f"calibrated {c.name}: planned -> "
              f"{cal.est_latency_s * 1e6:.1f}us "
              f"(scale {cal.serve['calibration']['scale']:.2f})")


if __name__ == "__main__":
    main()
