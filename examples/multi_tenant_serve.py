"""Multi-tenant serving example: co-resident networks behind one router.

  PYTHONPATH=src python examples/multi_tenant_serve.py

ONE facade call plans two extreme-edge nets AND a small LM as a fleet
(joint placement, per-tenant latency budgets, host-calibrated machine
model) and builds the engines; ``.serve()`` wires the multi-tenant router.
The example then drives mixed traffic — synchronous edge inferences
interleaved with continuous-batched LM requests — prints the per-tenant
report, and closes the loop with ``.recalibrate()`` (measured latencies
back into the plan cache, budgets re-derived).
"""

import jax
import numpy as np

from repro import configs
from repro.deploy import Deployment
from repro.models import api
from repro.serve.engine import Request


def main():
    lm_cfg = configs.get("qwen2_5_3b").smoke
    lm_params = api.init(lm_cfg, jax.random.PRNGKey(0))

    # One fleet: two edge tenants + one LM tenant.  machine_model="auto"
    # (the default) calibrates the planner to THIS host so budgets are
    # meaningful; engines are quantized + calibrated + jitted behind build.
    dep = Deployment.build(
        ["jet_tagger", "tau_select", lm_cfg],
        lm_params={lm_cfg.name: (lm_cfg, lm_params)},
        serve_slots_total=3, prefill_chunk=4)
    print(dep.summary())

    router = dep.serve()
    inputs = router.warmup()      # jit compile, then zero the counters

    # Mixed traffic: submit LM requests, then interleave edge inferences
    # with batcher ticks (the LM tenant decodes while edge nets serve).
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, lm_cfg.vocab_size,
                                        3).astype(np.int32),
                    max_new=4)
            for i in range(4)]
    for r in reqs:
        router.submit(lm_cfg.name, r)
    for tick in range(40):
        for name, x in inputs.items():
            router.infer(name, x)
        if router.step() == 0 and all(r.done for r in reqs):
            break

    print("\nper-tenant report:")
    for nid, m in router.report().items():
        print(f"  {nid:<14} n={m['count']:<3} mean={m['mean_s'] * 1e6:8.1f}us "
              f"p95={m['p95_s'] * 1e6:8.1f}us "
              f"violations={m['budget_violations']} "
              f"occupancy={m['occupancy']:.2f}")
    assert all(r.done for r in reqs)
    for r in reqs:
        print(f"  lm req {r.rid}: {len(r.out)} tokens")

    # Autotune feedback, one call: measured edge latencies land in the plan
    # cache and the fleet's costs + budgets are re-derived in place.
    fleet = dep.recalibrate()
    for t in fleet.tenants:
        if t.plan.kind == "edge":
            print(f"calibrated {t.net_id}: planned -> "
                  f"{t.plan.est_latency_s * 1e6:.1f}us "
                  f"(scale {t.plan.serve['calibration']['scale']:.2f})")


if __name__ == "__main__":
    main()
