"""End-to-end training driver: ~100M-param LM, fault-tolerant, checkpointed.

  PYTHONPATH=src python examples/train_100m.py --steps 300
  PYTHONPATH=src python examples/train_100m.py --steps 20 --quick   # CI-scale

Exercises the full production stack on one host: prefetching data pipeline,
remat + chunked-loss train step, AdamW with cosine schedule, async atomic
checkpoints, restart-on-failure (one injected failure), and straggler
detection — i.e. the same TrainDriver a pod deployment wraps around the
pjit-sharded step.
"""

import argparse
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.data.pipeline import synth_batch
from repro.models.config import ModelConfig
from repro.train import fault, optimizer, schedule, step as step_lib


def make_100m_config(quick: bool = False) -> ModelConfig:
    if quick:
        return ModelConfig(
            name="lm-quick", family="transformer", num_layers=2, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=512,
            vocab_size=2048, attn_pattern=("global",), tie_embeddings=True)
    return ModelConfig(
        name="lm-100m", family="transformer", num_layers=10, d_model=640,
        num_heads=10, num_kv_heads=5, head_dim=64, d_ff=2560,
        vocab_size=50_000, attn_pattern=("global",), tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", type=str, default=None)
    args = ap.parse_args()

    cfg = make_100m_config(args.quick)
    print(f"model: {cfg.name}  params~{cfg.param_count()/1e6:.0f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")

    opt = optimizer.make("adamw", lr=schedule.warmup_cosine(
        3e-4, warmup_steps=max(args.steps // 20, 2), total_steps=args.steps),
        weight_decay=0.01)
    init_fn, step_fn = step_lib.build_train_step(
        cfg, opt, step_lib.TrainOptions(remat="block", chunked_loss=True))
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    jstep = jax.jit(step_fn, donate_argnums=0)

    def batch_fn(step):
        return {k: jnp.asarray(v) for k, v in
                synth_batch(cfg, batch=args.batch, seq=args.seq,
                            step=step).items()}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_100m_")
    driver = fault.TrainDriver(
        cfg=fault.DriverConfig(ckpt_dir=ckpt_dir,
                               ckpt_every=max(args.steps // 6, 5)),
        step_fn=jstep, batch_fn=batch_fn, state=state)

    # Inject one node failure a third of the way in — the driver restarts
    # from the last checkpoint and replays deterministically.
    inject_at = {max(args.steps // 3, 3): True}

    def hook(step):
        if inject_at.pop(step, None):
            raise fault.SimulatedNodeFailure(f"injected at step {step}")

    # Progress logging wrapper.
    losses = []
    orig_step = driver.step_fn

    def logged(state, batch):
        new_state, m = orig_step(state, batch)
        # (read the step from the metrics — the input state buffer is donated)
        s = int(m["step"])
        losses.append(float(m["loss"]))
        if s % max(args.steps // 20, 1) == 0:
            print(f"  step {s:4d}  loss={losses[-1]:.4f}")
        return new_state, m

    driver.step_fn = logged
    driver.run(args.steps, failure_hook=hook)

    print(f"\nfinal step: {driver.step}")
    print(f"loss: first={losses[0]:.4f}  last={losses[-1]:.4f}  "
          f"(improved: {losses[-1] < losses[0]})")
    print(f"events: {[e[0] for e in driver.events]}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
