"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Two deployments through the ``repro.deploy`` facade:

  * the paper's extreme-edge regime in THREE lines — plan + quantize +
    calibrate + engines behind ``Deployment.build``, serving behind
    ``.serve()``;
  * a tiny gemma2-family LM trained for a few steps on synthetic data, then
    served through the same facade (plan-driven continuous batching).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import synth_batch
from repro.deploy import Deployment
from repro.serve.engine import Request
from repro.train import optimizer, schedule, step as step_lib


def main():
    # -- extreme-edge deployment (the paper's regime), in three lines --------
    dep = Deployment.build(["jet_tagger", "tau_select"])
    router = dep.serve()
    router.drive(router.warmup(), iters=5)
    print(dep.summary())
    for row in dep.bench():
        print(f"  {row.net_id}: planned {row.planned_s * 1e6:.0f}us, "
              f"measured {row.measured_s * 1e6:.0f}us "
              f"(within 2x: {row.within_2x})")

    # -- train a small LM ----------------------------------------------------
    arch = configs.get("gemma2-2b")          # --arch style lookup
    cfg = arch.smoke                          # reduced same-family config
    print(f"\narch={arch.name}  family={cfg.family}  "
          f"params~{cfg.param_count()/1e6:.1f}M (smoke)")
    opt = optimizer.make("adamw", lr=schedule.warmup_cosine(
        3e-3, warmup_steps=5, total_steps=50))
    init_fn, step_fn = step_lib.build_train_step(
        cfg, opt, step_lib.TrainOptions(remat="block", chunked_loss=True))
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    jstep = jax.jit(step_fn, donate_argnums=0)
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in
                 synth_batch(cfg, batch=8, seq=64, step=i).items()}
        state, metrics = jstep(state, batch)
        if i % 3 == 0:
            print(f"step {i:3d}  loss={float(metrics['loss']):.3f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}")

    # -- serve the trained weights through the same facade -------------------
    lm = Deployment.build([cfg], machine_model=None,
                          lm_params={cfg.name: (cfg, state["params"])})
    lm_router = lm.serve()
    req = Request(rid=0, prompt=np.array([5, 17, 42], np.int32), max_new=8)
    lm_router.submit(cfg.name, req)
    lm_router.run_until_drained()
    print("decoded token ids:", req.out)


if __name__ == "__main__":
    main()
