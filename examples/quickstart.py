"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Builds a tiny gemma2-family model, trains a few steps on synthetic data,
then serves a short greedy decode — the same code paths the 512-chip
dry-run compiles, at laptop scale.
"""

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import synth_batch
from repro.models import api
from repro.serve import engine
from repro.train import optimizer, schedule, step as step_lib


def main():
    arch = configs.get("gemma2-2b")          # --arch style lookup
    cfg = arch.smoke                          # reduced same-family config
    print(f"arch={arch.name}  family={cfg.family}  "
          f"params~{cfg.param_count()/1e6:.1f}M (smoke)")

    # -- train ---------------------------------------------------------------
    opt = optimizer.make("adamw", lr=schedule.warmup_cosine(
        3e-3, warmup_steps=5, total_steps=50))
    init_fn, step_fn = step_lib.build_train_step(
        cfg, opt, step_lib.TrainOptions(remat="block", chunked_loss=True))
    state = jax.jit(init_fn)(jax.random.PRNGKey(0))
    jstep = jax.jit(step_fn, donate_argnums=0)
    for i in range(10):
        batch = {k: jnp.asarray(v) for k, v in
                 synth_batch(cfg, batch=8, seq=64, step=i).items()}
        state, metrics = jstep(state, batch)
        if i % 3 == 0:
            print(f"step {i:3d}  loss={float(metrics['loss']):.3f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}")

    # -- serve ---------------------------------------------------------------
    params = state["params"]
    batcher = engine.ContinuousBatcher(cfg, params, slots=2, max_len=64)
    import numpy as np
    req = engine.Request(rid=0, prompt=np.array([5, 17, 42], np.int32),
                         max_new=8)
    batcher.submit(req)
    batcher.run_until_drained()
    print("decoded token ids:", req.out)


if __name__ == "__main__":
    main()
