"""The paper's scenario end-to-end: deploy extreme-edge trigger networks
through the staged facade (``repro.deploy`` over ``repro.plan``).

  PYTHONPATH=src python examples/edge_trigger_deployment.py

For each Table-I workload (VAE, qubit readout, deep autoencoder):
  1. the planner runs LARE (Alg. 1) per layer, searches spatial splits and
     API tiles (Alg. 2) under column/band constraints, and charges boundary
     crossings (DR7) — emitting a serializable DeploymentPlan;
  2-3. ``Deployment.build`` quantizes the weights to int8 (the paper's
     datatype convention) and executes the TPU-path plan via the fused
     Pallas int8 kernels (interpret mode on CPU — identical code compiles
     to Mosaic on TPU);
  4. the paper-faithful AIE plan reports whether the deployment meets the
     40 MHz LHC level-1 trigger rate.
"""

import jax
import jax.numpy as jnp

from repro.deploy import Deployment
from repro.models import edge


def main():
    pl_budget_per_layer = 400.0     # DSP-equivalents available per layer
    for name in ("vae", "qubit", "autoencoder"):
        cfg = edge.edge_config(name)
        print(f"\n=== {name}: dims={list(cfg.dims)}  macs={cfg.macs} ===")

        # 1. Plan the deployment (paper-faithful AIE path, plan-only).
        plan = Deployment.build(name, target="aie", machine_model=None,
                                stop_after="plan",
                                pl_budget=pl_budget_per_layer).plan
        for l in plan.layers:
            print(f"  layer {l.n_in:4d}->{l.n_out:4d}: LARE={l.lare:8.1f} "
                  f"P_KxP_N={l.p_k}x{l.p_n} band={l.band}"
                  f"  -> deploy on {l.regime.upper()}")
        for b in plan.boundaries:
            print(f"  boundary after layer {b.after_layer}: "
                  f"{b.from_regime}->{b.to_regime} "
                  f"(+{b.crossing_s * 1e6:.2f}us, DR7)")

        # 2-3. int8 deployment executed through the TPU-path plan: the
        # facade builds the quantized, plan-driven engine in one call.
        dep = Deployment.build(name, machine_model=None, x_scale=0.02)
        eng = dep.engines[name]
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.dims[0])) * 0.5
        y_f = edge.edge_forward(
            edge.init_edge(jax.random.PRNGKey(0), cfg), cfg, x)
        y_q = eng.infer(x)
        agree = float(jnp.mean((jnp.argmax(y_f, -1) == jnp.argmax(y_q, -1))
                               .astype(jnp.float32)))
        print(f"  planned int8 path: output {tuple(y_q.shape)}, "
              f"argmax agreement vs float = {agree:.2f}  "
              f"(plan key {eng.plan.key[:12]}…)")

        # 4. All-AIE plan (pl_budget=0) vs the 40 MHz target.
        opt = Deployment.build(name, target="aie", machine_model=None,
                               stop_after="plan", pl_budget=0.0).plan
        mhz = opt.inferences_per_s / 1e6
        print(f"  planned AIE deployment: {mhz:5.1f} MHz  "
              f"({'MEETS' if mhz >= 40 else 'MISSES'} 40 MHz trigger)")


if __name__ == "__main__":
    main()
