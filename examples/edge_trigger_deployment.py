"""The paper's scenario end-to-end: deploy extreme-edge trigger networks.

  PYTHONPATH=src python examples/edge_trigger_deployment.py

For each Table-I workload (VAE, qubit readout, deep autoencoder):
  1. LARE (Alg. 1) decides the substrate per layer under a PL budget;
  2. weights are int8-quantized (the paper's datatype convention);
  3. inference runs through the fused Pallas int8 kernels (interpret mode on
     CPU — identical code compiles to Mosaic on TPU);
  4. the AIE design-rule interval model reports whether the deployment meets
     the 40 MHz LHC level-1 trigger rate.
"""

import jax
import jax.numpy as jnp

from repro.core import lare, tiling
from repro.models import edge


def main():
    pl_budget_per_layer = 400.0     # DSP-equivalents available per layer
    for name in ("vae", "qubit", "autoencoder"):
        cfg = edge.edge_config(name)
        print(f"\n=== {name}: dims={list(cfg.dims)}  macs={cfg.macs} ===")

        # 1. LARE decision per layer.
        for n_in, n_out in cfg.layer_shapes:
            r = lare.lare(n_in, n_out)
            choice = r.decide(pl_budget_per_layer)
            print(f"  layer {n_in:4d}->{n_out:4d}: LARE={r.lare:8.1f} "
                  f"rf_eq={r.rf_eq:7.1f}  -> deploy on {choice.upper()}")

        # 2-3. int8 deployment through the fused kernels.
        params = edge.init_edge(jax.random.PRNGKey(0), cfg)
        qparams = edge.quantize_edge(params)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (cfg.batch, cfg.dims[0])) * 0.5
        y_f = edge.edge_forward(params, cfg, x)
        y_q = edge.edge_forward_q8(qparams, cfg, x, x_scale=0.02)
        agree = float(jnp.mean((jnp.argmax(y_f, -1) == jnp.argmax(y_q, -1))
                               .astype(jnp.float32)))
        print(f"  int8 kernel path: output {tuple(y_q.shape)}, "
              f"argmax agreement vs float = {agree:.2f}")

        # 4. Design-rule interval (model) vs the 40 MHz target.
        t_naive = max(tiling.aie_tile_interval(cfg.batch, i, o)
                      for i, o in cfg.layer_shapes)
        t_opt = tiling.aie_optimized_interval(cfg.layer_shapes, cfg.batch)
        mhz = cfg.batch / t_opt / 1e6
        print(f"  AIE naive {cfg.batch/t_naive/1e6:5.1f} MHz -> "
              f"design rules {mhz:5.1f} MHz  "
              f"({'MEETS' if mhz >= 40 else 'MISSES'} 40 MHz trigger)")


if __name__ == "__main__":
    main()
