"""Batched serving example: continuous batching + int8 weights.

  PYTHONPATH=src python examples/serve_batch.py

Loads a small qwen2.5-family model, int8-quantizes the matmul weights
(runtime.maybe_dequant expands them per layer inside the scan — at-rest HBM
stays int8), then drives a continuous batcher over a stream of requests with
different prompt lengths and budgets.
"""

import numpy as np
import jax

from repro import configs
from repro.models import api
from repro.serve import engine


def main():
    cfg = configs.get("qwen2_5_3b").smoke
    params = api.init(cfg, jax.random.PRNGKey(0))
    qparams = engine.quantize_params(params, min_size=1024)
    before, after = engine.quantized_bytes(qparams)
    print(f"weights: {before/1e6:.2f} MB bf16 -> {after/1e6:.2f} MB int8+bf16 "
          f"({before/after:.2f}x smaller at rest)")

    batcher = engine.ContinuousBatcher(cfg, qparams, slots=3, max_len=96)
    rng = np.random.default_rng(0)
    requests = [
        engine.Request(rid=i,
                       prompt=rng.integers(1, cfg.vocab_size,
                                           rng.integers(2, 8)).astype(np.int32),
                       max_new=int(rng.integers(4, 10)))
        for i in range(8)
    ]
    for r in requests:
        batcher.submit(r)
    batcher.run_until_drained()
    for r in requests:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> "
              f"{len(r.out)} tokens: {r.out}")
    assert all(r.done for r in requests)
    print("all requests drained")


if __name__ == "__main__":
    main()
